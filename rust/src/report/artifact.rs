//! Machine-readable benchmark artifacts (`BENCH_<suite>.json`).
//!
//! Every number the repo reports — kernel MAC/cycle grids, end-to-end
//! network runs, autotuner totals, serve-fleet metrics — historically
//! only existed as pretty-printed tables. This module gives them a
//! persistent, versioned, machine-diffable form:
//!
//! - [`Json`]: a tiny zero-dependency JSON value (writer + parser), so
//!   the offline build needs no serde;
//! - [`MetricRow`]: one metric — a stable id, a value, a unit, and a
//!   [`MetricKind`] deciding how `regress` compares it against a
//!   baseline (`Exact`: simulated-cycle metrics are bit-deterministic
//!   and compare exactly; `Analog`: energy-model outputs such as TOPS/W
//!   and µJ/request get a tolerance band), plus an optional paper
//!   reference value for reproduction-distance reporting;
//! - [`BenchArtifact`]: a suite of rows plus run metadata (git
//!   revision, seed, simulated-cluster config), serialized to a stable
//!   pretty-printed JSON document. Serialization is bit-deterministic:
//!   two runs of the same binary on the same commit produce identical
//!   bytes (asserted by CI's double-run gate);
//! - [`MetricSource`]: the one trait every metric producer implements
//!   ([`crate::serve::FleetMetrics`], the autotuner's
//!   [`crate::dory::autotune::TunedModelMetrics`], and the kernel/e2e
//!   sources in [`crate::report::bench`]) so tables, benches, and
//!   artifacts all draw from the same rows and can never diverge.
//!
//! Schema stability: unknown object fields are ignored on parse
//! (forward compatibility for added fields), while a `schema_version`
//! above [`SCHEMA_VERSION`] is rejected (a newer writer may have
//! changed the meaning of existing fields). Duplicate row ids are
//! rejected on both ends. See `rust/tests/bench_artifact.rs`.

/// Current artifact schema version. Bump when the meaning of existing
/// fields changes; purely additive fields do not need a bump.
pub const SCHEMA_VERSION: u32 = 1;

/// The `"schema"` tag stamped into every artifact.
pub const SCHEMA_NAME: &str = "flexv-bench-artifact";

// ---------------------------------------------------------------------------
// JSON value: writer + parser (zero-dependency).
// ---------------------------------------------------------------------------

/// A JSON value. Objects preserve insertion order (a `Vec`, not a map),
/// which is what makes rendering deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Pretty-print with 2-space indentation (committed baselines stay
    /// line-diffable). Deterministic: field order is insertion order and
    /// numbers use Rust's shortest round-trip formatting.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => out.push_str(&fmt_num(*v)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (exactly one value plus whitespace).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Field of an object (`None` for missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Shortest round-trip decimal of a finite f64 (Rust's `Display`
/// contract); JSON has no NaN/Inf, so non-finite values become `null`.
fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => {
                self.i += 1;
                Ok(Json::Str(self.string()?))
            }
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.ws();
                    items.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    self.expect(b'"')?;
                    let key = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    let val = self.value()?;
                    fields.push((key, val));
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    /// Body of a string; the opening quote is already consumed.
    fn string(&mut self) -> Result<String, String> {
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".to_string());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".to_string());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: expect \uDC00..DFFF
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err("bad low surrogate".to_string());
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err("lone high surrogate".to_string());
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i - 1)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                b0 => {
                    // Multibyte character: decode exactly its UTF-8
                    // width (the input is a valid &str, so the lead
                    // byte's width lands on a char boundary).
                    self.i -= 1;
                    let len = match b0 {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.i + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[self.i..end])
                        .map_err(|e| e.to_string())?;
                    let ch = s.chars().next().ok_or("bad utf-8 sequence")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|e| format!("bad \\u escape: {e}"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

// ---------------------------------------------------------------------------
// Metric rows.
// ---------------------------------------------------------------------------

/// How `regress` compares a metric against its baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// A pure function of simulated cycles/counters — bit-deterministic,
    /// compared exactly (modulo `--tol-cycles`, default 0).
    Exact,
    /// Output of the calibrated analog/energy model (TOPS/W, µJ, mW) —
    /// compared within the `--tol-power` relative band.
    Analog,
}

impl MetricKind {
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::Exact => "exact",
            MetricKind::Analog => "analog",
        }
    }

    pub fn from_name(s: &str) -> Option<MetricKind> {
        match s {
            "exact" => Some(MetricKind::Exact),
            "analog" => Some(MetricKind::Analog),
            _ => None,
        }
    }
}

/// One metric of a benchmark artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricRow {
    /// Stable, unique, slash-separated id (e.g.
    /// `kernels/matmul/flexv/a2w2/mac_per_cycle`).
    pub id: String,
    pub value: f64,
    /// Human-readable unit (`cycles`, `MAC/cycle`, `TOPS/W`, `uJ/req`…).
    pub unit: String,
    pub kind: MetricKind,
    /// The paper's reported value for this metric, where it reports one
    /// (Table III/IV anchors) — drives the reproduction-distance table.
    pub paper: Option<f64>,
}

impl MetricRow {
    pub fn exact(id: impl Into<String>, value: f64, unit: &str) -> MetricRow {
        MetricRow { id: id.into(), value, unit: unit.into(), kind: MetricKind::Exact, paper: None }
    }

    pub fn analog(id: impl Into<String>, value: f64, unit: &str) -> MetricRow {
        MetricRow { id: id.into(), value, unit: unit.into(), kind: MetricKind::Analog, paper: None }
    }

    pub fn with_paper(mut self, v: f64) -> MetricRow {
        self.paper = Some(v);
        self
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id".to_string(), Json::Str(self.id.clone())),
            ("value".to_string(), Json::Num(self.value)),
            ("unit".to_string(), Json::Str(self.unit.clone())),
            ("kind".to_string(), Json::Str(self.kind.name().to_string())),
        ];
        if let Some(p) = self.paper {
            fields.push(("paper".to_string(), Json::Num(p)));
        }
        Json::Obj(fields)
    }

    fn from_json(j: &Json) -> Result<MetricRow, String> {
        let id = j
            .get("id")
            .and_then(Json::as_str)
            .ok_or("row missing string 'id'")?
            .to_string();
        let value = j
            .get("value")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("row '{id}' missing numeric 'value'"))?;
        let unit = j.get("unit").and_then(Json::as_str).unwrap_or("").to_string();
        let kind = match j.get("kind").and_then(Json::as_str) {
            Some(k) => MetricKind::from_name(k)
                .ok_or_else(|| format!("row '{id}': unknown kind '{k}'"))?,
            None => MetricKind::Exact,
        };
        let paper = j.get("paper").and_then(Json::as_f64);
        Ok(MetricRow { id, value, unit, kind, paper })
    }
}

/// Anything that can emit artifact rows. Implemented by the serve
/// fleet report, the autotuner's per-model summary, and the kernel /
/// end-to-end sources — the single path every table, bench, and
/// `bench-report` run draws numbers from.
pub trait MetricSource {
    /// Stable, fully-qualified metric rows. Only simulated
    /// (host-independent) quantities may appear here — never wall-clock
    /// times or host-side cache counters.
    fn metric_rows(&self) -> Vec<MetricRow>;
}

// ---------------------------------------------------------------------------
// Run metadata + the artifact itself.
// ---------------------------------------------------------------------------

/// Provenance of one artifact run. `regress` ignores all of it (only
/// rows are compared); it exists so a checked-in or uploaded artifact
/// is self-describing.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RunMeta {
    /// `git rev-parse` of the producing tree (`unknown` outside a repo).
    pub git_rev: String,
    /// Primary PRNG seed of the suite's workloads.
    pub seed: u64,
    /// Quick-mode inputs (96×96 MobileNet) vs the paper's full 224×224.
    pub quick: bool,
    /// Simulated-cluster configuration summary.
    pub sim: String,
}

impl RunMeta {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("git_rev".to_string(), Json::Str(self.git_rev.clone())),
            ("seed".to_string(), Json::Num(self.seed as f64)),
            ("quick".to_string(), Json::Bool(self.quick)),
            ("sim".to_string(), Json::Str(self.sim.clone())),
        ])
    }

    fn from_json(j: &Json) -> RunMeta {
        RunMeta {
            git_rev: j.get("git_rev").and_then(Json::as_str).unwrap_or("unknown").to_string(),
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
            quick: j.get("quick").and_then(Json::as_bool).unwrap_or(false),
            sim: j.get("sim").and_then(Json::as_str).unwrap_or("").to_string(),
        }
    }
}

/// One benchmark suite's metric rows plus run metadata, serializable to
/// a stable `BENCH_<suite>.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchArtifact {
    pub suite: String,
    pub schema_version: u32,
    /// A committed baseline that has not been pinned to measured values
    /// yet (its rows are paper targets only): `regress` reports
    /// reproduction distance but does not gate on it until
    /// `regress --bless` replaces it with measured numbers.
    pub pending: bool,
    pub meta: RunMeta,
    pub rows: Vec<MetricRow>,
}

impl BenchArtifact {
    pub fn new(suite: impl Into<String>, meta: RunMeta) -> BenchArtifact {
        BenchArtifact {
            suite: suite.into(),
            schema_version: SCHEMA_VERSION,
            pending: false,
            meta,
            rows: Vec::new(),
        }
    }

    /// Canonical file name of a suite's artifact.
    pub fn file_name(suite: &str) -> String {
        format!("BENCH_{suite}.json")
    }

    /// Append every row of a source. Panics on duplicate ids — row ids
    /// are the join key of the whole regression pipeline.
    pub fn push_source(&mut self, src: &dyn MetricSource) {
        for row in src.metric_rows() {
            assert!(
                self.row(&row.id).is_none(),
                "duplicate metric id '{}' in suite '{}'",
                row.id,
                self.suite
            );
            self.rows.push(row);
        }
    }

    /// Look up a row by id.
    pub fn row(&self, id: &str) -> Option<&MetricRow> {
        self.rows.iter().find(|r| r.id == id)
    }

    /// Serialize to the canonical JSON document (deterministic bytes).
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("schema".to_string(), Json::Str(SCHEMA_NAME.to_string())),
            ("schema_version".to_string(), Json::Num(self.schema_version as f64)),
            ("suite".to_string(), Json::Str(self.suite.clone())),
        ];
        if self.pending {
            fields.push(("pending".to_string(), Json::Bool(true)));
        }
        fields.push(("meta".to_string(), self.meta.to_json()));
        fields.push(("rows".to_string(), Json::Arr(self.rows.iter().map(|r| r.to_json()).collect())));
        Json::Obj(fields).render()
    }

    /// Parse an artifact document. Unknown fields are ignored (forward
    /// compatibility); a newer `schema_version`, a missing `suite`, or
    /// duplicate row ids are errors.
    pub fn from_json(s: &str) -> Result<BenchArtifact, String> {
        let j = Json::parse(s)?;
        let version = j
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or("missing numeric 'schema_version'")?;
        if version > SCHEMA_VERSION as u64 {
            return Err(format!(
                "artifact schema v{version} is newer than this binary's v{SCHEMA_VERSION} — \
                 rebuild or regenerate the artifact"
            ));
        }
        let suite = j
            .get("suite")
            .and_then(Json::as_str)
            .ok_or("missing string 'suite'")?
            .to_string();
        let pending = j.get("pending").and_then(Json::as_bool).unwrap_or(false);
        let meta = j.get("meta").map(RunMeta::from_json).unwrap_or_default();
        let rows_json = j.get("rows").and_then(Json::as_arr).ok_or("missing array 'rows'")?;
        let mut rows = Vec::with_capacity(rows_json.len());
        for rj in rows_json {
            let row = MetricRow::from_json(rj)?;
            if rows.iter().any(|r: &MetricRow| r.id == row.id) {
                return Err(format!("duplicate row id '{}'", row.id));
            }
            rows.push(row);
        }
        Ok(BenchArtifact { suite, schema_version: version as u32, pending, meta, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrips_values() {
        let doc = r#"{"a": [1, -2.5, 1e3], "b": {"c": "x\nyé"}, "d": true, "e": null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2], Json::Num(1000.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\nyé");
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e"), Some(&Json::Null));
        // render → parse is the identity
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}{}").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn float_formatting_is_shortest_roundtrip() {
        for v in [0.1, 1.0 / 3.0, 91.5, 3.26, 12345678901234.0, -0.0625] {
            let j = Json::Num(v);
            let back = Json::parse(j.render().trim()).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn artifact_roundtrip_and_unknown_fields() {
        let mut a = BenchArtifact::new(
            "kernels",
            RunMeta { git_rev: "abc".into(), seed: 7, quick: true, sim: "8 cores".into() },
        );
        a.rows.push(MetricRow::exact("kernels/x/cycles", 12345.0, "cycles"));
        a.rows.push(MetricRow::analog("kernels/x/tops_w", 3.26, "TOPS/W").with_paper(3.26));
        let text = a.to_json();
        let b = BenchArtifact::from_json(&text).unwrap();
        assert_eq!(a, b);
        // serialization is deterministic
        assert_eq!(text, b.to_json());
    }

    #[test]
    fn version_and_duplicate_handling() {
        let newer = r#"{"schema_version": 999, "suite": "x", "rows": []}"#;
        assert!(BenchArtifact::from_json(newer).is_err());
        let dup = r#"{"schema_version": 1, "suite": "x", "rows": [
            {"id": "a", "value": 1}, {"id": "a", "value": 2}]}"#;
        assert!(BenchArtifact::from_json(dup).is_err());
        let missing_suite = r#"{"schema_version": 1, "rows": []}"#;
        assert!(BenchArtifact::from_json(missing_suite).is_err());
    }
}
