//! Generator for the Quantization phase (§II-B): bring a block of 32-bit
//! accumulators back to the low-bitwidth output format with one MAC-class
//! op, one shift and one clip per output, then repack sub-byte outputs.

use super::regalloc as ra;
use crate::isa::{AluOp, Instr, Program, Reg};

/// Requantization configuration of a MatMul/conv kernel.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct RequantCfg {
    /// TCDM base of the per-channel i32 multiplier array.
    pub mult_base: u32,
    /// TCDM base of the per-channel i32 bias array.
    pub bias_base: u32,
    /// Arithmetic right shift.
    pub shift: u8,
    /// Output bit-width (2/4/8, unsigned).
    pub out_bits: u8,
}

/// Emit the requant + store sequence for a block of `nb` rows × `nf`
/// filter outputs whose accumulators sit in `ra::acc(f*nb + b)`.
///
/// `out_addr(b)` gives the TCDM byte address of output element
/// `(row b, channel n_base)`; channels `n_base..n_base+nf` are consecutive
/// in HWC so the `nf` outputs of one row pack into `nf*out_bits` bits.
/// Requires `nf*out_bits % 8 == 0` (byte-aligned stores, the DORY
/// invariant) and `nf <= 4`.
pub fn emit_requant_block(
    p: &mut Program,
    cfg: &RequantCfg,
    n_base: usize,
    nf: usize,
    nb: usize,
    out_addr: impl Fn(usize) -> u32,
) {
    assert!(nf <= 4 && nf * cfg.out_bits as usize % 8 == 0);
    // Per-filter multiplier/bias loads (hoisted; W/A regs are dead here).
    // mult_f -> W_REG[f], bias_f -> TMP[f].
    for f in 0..nf {
        p.push(Instr::Li {
            rd: ra::Q_PTR,
            imm: (cfg.mult_base + 4 * (n_base + f) as u32) as i32,
        });
        p.push(Instr::Lw { rd: ra::W_REG[f], base: ra::Q_PTR, off: 0, post_inc: 0 });
        p.push(Instr::Li {
            rd: ra::Q_PTR,
            imm: (cfg.bias_base + 4 * (n_base + f) as u32) as i32,
        });
        p.push(Instr::Lw { rd: ra::TMP[f], base: ra::Q_PTR, off: 0, post_inc: 0 });
    }
    for b in 0..nb {
        // Requantize the nf outputs of row b in place (accumulator regs).
        for f in 0..nf {
            let a: Reg = ra::acc(f * nb + b);
            // acc += bias  (the "one MAC" of the paper folds bias+scale;
            // we cost the same three ops: add/mul, shift, clip)
            p.push(Instr::Alu { op: AluOp::Add, rd: a, rs1: a, rs2: ra::TMP[f] });
            p.push(Instr::Alu { op: AluOp::Mul, rd: a, rs1: a, rs2: ra::W_REG[f] });
            p.push(Instr::AluI { op: AluOp::Sra, rd: a, rs1: a, imm: cfg.shift as i32 });
            p.push(Instr::Clipu { rd: a, rs1: a, bits: cfg.out_bits });
        }
        // Pack the nf outputs of row b into one word via p.insert.
        let pack: Reg = ra::A_REG[0]; // dead after the K-loop
        for f in 0..nf {
            if f == 0 {
                // first insert also clears the word: mov via ALU
                p.push(Instr::Alu {
                    op: AluOp::Add,
                    rd: pack,
                    rs1: ra::acc(b), // f == 0
                    rs2: 0,
                });
            } else {
                p.push(Instr::Insert {
                    rd: pack,
                    rs1: ra::acc(f * nb + b),
                    off: (f * cfg.out_bits as usize) as u8,
                    len: cfg.out_bits,
                });
            }
        }
        // Store the packed bits (byte-aligned by the assertion above).
        let bytes = nf * cfg.out_bits as usize / 8;
        p.push(Instr::Li { rd: ra::OUT_PTR, imm: out_addr(b) as i32 });
        match bytes {
            4 => {
                p.push(Instr::Sw { rs: pack, base: ra::OUT_PTR, off: 0, post_inc: 0 });
            }
            _ => {
                // store byte by byte (1 or 2 bytes)
                let shreg: Reg = ra::A_REG[1];
                for byte in 0..bytes {
                    if byte == 0 {
                        p.push(Instr::Sb { rs: pack, base: ra::OUT_PTR, off: 0, post_inc: 0 });
                    } else {
                        p.push(Instr::AluI {
                            op: AluOp::Srl,
                            rd: shreg,
                            rs1: pack,
                            imm: 8 * byte as i32,
                        });
                        p.push(Instr::Sb {
                            rs: shreg,
                            base: ra::OUT_PTR,
                            off: byte as i32,
                            post_inc: 0,
                        });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;
    use crate::sim::{ClusterMem, Core, TCDM_BASE};

    fn run(prog: Program, mem: &mut ClusterMem, setup: impl FnOnce(&mut Core)) -> Core {
        let mut c = Core::new(0);
        c.load_program(prog);
        setup(&mut c);
        while !c.halted() {
            let granted = c.mem_request().is_some();
            c.tick(mem, granted);
        }
        c
    }

    #[test]
    fn requant_block_matches_reference() {
        // 4 filters x 2 rows; acc(f*2+b) preset; mult/bias in TCDM.
        let mut mem = ClusterMem::new();
        let mult_base = TCDM_BASE;
        let bias_base = TCDM_BASE + 64;
        let out_base = TCDM_BASE + 128;
        let mults = [3i32, 5, 7, 11];
        let biases = [100i32, -50, 0, 25];
        for f in 0..4 {
            mem.store_u32(mult_base + 4 * f as u32, mults[f] as u32);
            mem.store_u32(bias_base + 4 * f as u32, biases[f] as u32);
        }
        let cfg = RequantCfg { mult_base, bias_base, shift: 6, out_bits: 8 };
        let accs: [[i32; 2]; 4] = [[500, -200], [1000, 40], [77, 3000], [-5, 9999]];

        let mut p = Program::new("rq");
        emit_requant_block(&mut p, &cfg, 0, 4, 2, |b| out_base + 4 * b as u32);
        p.push(Instr::Halt);
        run(p, &mut mem, |c| {
            for f in 0..4 {
                for b in 0..2 {
                    c.regs[ra::acc(f * 2 + b) as usize] = accs[f][b] as u32;
                }
            }
        });

        let q = crate::qnn::QuantParams {
            mult: mults.to_vec(),
            shift: 6,
            bias: biases.to_vec(),
            out_bits: 8,
        };
        for b in 0..2 {
            let word = mem.load_u32(out_base + 4 * b as u32);
            for f in 0..4 {
                let got = (word >> (8 * f)) & 0xFF;
                let want = q.requant(accs[f][b], f);
                assert_eq!(got, want, "f={f} b={b}");
            }
        }
    }

    #[test]
    fn requant_subbyte_packing() {
        // out_bits=2: 4 filter outputs pack into one byte.
        let mut mem = ClusterMem::new();
        let cfg = RequantCfg {
            mult_base: TCDM_BASE,
            bias_base: TCDM_BASE + 16,
            shift: 0,
            out_bits: 2,
        };
        for f in 0..4u32 {
            mem.store_u32(TCDM_BASE + 4 * f, 1);
            mem.store_u32(TCDM_BASE + 16 + 4 * f, 0);
        }
        let mut p = Program::new("rq2");
        emit_requant_block(&mut p, &cfg, 0, 4, 1, |_| TCDM_BASE + 64);
        p.push(Instr::Halt);
        run(p, &mut mem, |c| {
            // accs 1, 2, 3, 99(clips to 3)
            c.regs[ra::acc(0) as usize] = 1;
            c.regs[ra::acc(1) as usize] = 2;
            c.regs[ra::acc(2) as usize] = 3;
            c.regs[ra::acc(3) as usize] = 99;
        });
        // packed little-endian: 1 | 2<<2 | 3<<4 | 3<<6 = 0b11_11_10_01
        assert_eq!(mem.load_u8(TCDM_BASE + 64), 0b1111_1001);
    }
}
