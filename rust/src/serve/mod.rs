//! Multi-cluster inference **serving engine**: request queueing, dynamic
//! batching, a compiled-plan cache, and a pool of simulated cluster
//! shards (workload → queue → batcher → shard pool → metrics; see
//! `rust/src/serve/README.md`).
//!
//! The one-shot pipeline (`dory::deploy` → `coordinator`) runs a single
//! `Deployment` on a single cluster and exits. This module is the layer
//! the ROADMAP's production north star needs on top of it:
//!
//! - a [`workload`] engine generating deterministic open-loop arrival
//!   traces (steady / Poisson / bursty / diurnal, multi-model mixes,
//!   SLO classes with priorities and deadlines);
//! - a [`PlanCache`] keyed by [`crate::dory::PlanKey`] so the DORY flow
//!   (tiling solve, L2 layout, weight serialization) runs **once per
//!   model**, not once per request;
//! - a bounded priority [`RequestQueue`] with explicit rejection stats,
//!   earliest-deadline-first ordering within a priority level, and
//!   shed-before-simulate load shedding of requests whose deadline can
//!   no longer be met — graceful saturation instead of unbounded
//!   latency collapse;
//! - a dynamic [`batcher`] that coalesces queued same-model requests
//!   onto one shard pass, amortizing the L3→L2 model-switch cost the
//!   same way PULP-NN amortizes im2col/packing across calls;
//! - a pool of [`Shard`]s, each owning one simulated PULP cluster, driven
//!   in a deterministic discrete-event loop over **simulated cycles**
//!   (scaling one core's precision-flexible datapath to a fleet, as
//!   Dustin does on-die with 16 cores), elastically grown and shrunk by
//!   the [`autoscale`]r between dispatch rounds;
//! - per-request, per-class, and fleet [`metrics`]: latency percentiles,
//!   deadline-miss rates, shed counts, requests/sec, aggregate
//!   MAC/cycle, energy per request, shard-occupancy timeline.
//!
//! # Energy awareness
//!
//! Every shard batch runs at a voltage/frequency **operating point**
//! ([`crate::power::operating_points`]) chosen by the engine's DVFS
//! governor from [`ServeConfig::dvfs`] (race-to-idle, slow-and-steady,
//! per-SLO-class, or fixed) and clamped by an optional fleet power cap
//! ([`ServeConfig::power_cap_mw`]): at dispatch the governor sums a
//! conservative busy-power bound over the work already in flight and
//! downgrades the new batch's point — or leaves the shard idle for the
//! round — until the sum fits under the cap (one busy shard is always
//! allowed, so a tiny cap degrades to serialized efficiency-point
//! service instead of deadlock). Shard clocks stay in nominal fleet
//! ticks ([`crate::power::OperatingPoint::fleet_ticks`]), and energy is
//! billed at each batch's corner, so `FleetMetrics` can report energy
//! per request, fleet average power, and fleet TOPS/W.
//!
//! # Determinism contract
//!
//! Everything the engine reports is a function of the trace alone —
//! never of the host machine, worker count, or fast-path setting:
//!
//! - **Scheduling** (queue pops, shedding, autoscaling, batch formation,
//!   shard assignment — and every DVFS/power-cap decision: operating
//!   points are chosen during sequential batch formation from simulated
//!   state only, never measured host load) runs sequentially on the
//!   engine thread, in shard order, so the decision stream is
//!   reproducible by construction.
//! - **Execution** of the formed batches is embarrassingly parallel
//!   (each shard owns its cluster); with `workers != 1` the batches of a
//!   dispatch round run on a scoped `std::thread` pool. The round's
//!   completion events are then merged by simulated finish cycle
//!   (tie-break: shard id, then request id) — the sequential engine
//!   applies the *same* reduction, so `completions()` is bit-identical
//!   for any worker count (`rust/tests/serve_parallel_determinism.rs`,
//!   `rust/tests/serve_workload.rs`).
//! - The simulator's steady-state fast path (`ServeConfig::fastpath`,
//!   see [`crate::sim::fastpath`]) replays previously-seen windows with
//!   bit-exact outputs and cycle counts; `fastpath: false` is the
//!   escape hatch and must change nothing but wall-clock time.
//!
//! With `exact: true` every request additionally runs on a pristine
//! cluster, making serve-path outputs and per-layer cycle counts
//! bit-identical to a direct [`crate::coordinator::Coordinator`] run
//! (asserted by `rust/tests/serve_determinism.rs`). The default
//! `exact: false` keeps clusters and tile-timing memos warm for
//! throughput, at the cost of timing-only outputs (see
//! `coordinator::execute_deployment`).

pub mod autoscale;
pub mod batcher;
pub mod cache;
pub mod federation;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod shard;
pub mod workload;

pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
pub use batcher::BatchPolicy;
pub use cache::PlanCache;
pub use federation::{
    FaultPlan, Federation, FederationConfig, FederationMetrics, RolloutPlan, RolloutReport,
    RouterPolicy,
};
pub use metrics::{ClassRow, FleetMetrics, ModelRow, TunedSummary};
pub use queue::RequestQueue;
pub use request::{Completion, Request, ShedEvent};
pub use shard::Shard;
pub use workload::{SloClass, TraceShape, WorkloadSpec};

use std::sync::Arc;

use crate::dory::autotune::{self, TuneCache, TuneConfig};
use crate::dory::deploy::{deploy, deploy_tuned, Deployment};
use crate::dory::{MemBudget, PlanKey};
use crate::isa::IsaVariant;
use crate::power::{operating_points, DvfsPolicy, EnergyModel, OP_BOOST, OP_EFFICIENCY, OP_NOMINAL};
use crate::qnn::layer::Network;
use crate::qnn::QTensor;
use crate::sim::CoreFidelity;
use crate::util::Prng;

/// Fleet configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Number of cluster shards in the pool.
    pub shards: usize,
    /// Cores per shard cluster.
    pub n_cores: usize,
    /// Admission queue bound (requests beyond it are rejected;
    /// 0 admits nothing).
    pub queue_capacity: usize,
    /// Maximum requests coalesced into one shard pass.
    pub max_batch: usize,
    /// Lead-request shard affinity (avoid model switches when possible).
    pub prefer_resident: bool,
    /// Pristine cluster per request: bit-identical to the one-shot
    /// coordinator path (slow). Off: warm clusters + tile-timing memo.
    pub exact: bool,
    /// Host threads simulating shard batches concurrently within one
    /// dispatch round: 0 = one thread per busy shard (default), 1 =
    /// sequential. Results are bit-identical for any value — see the
    /// module-level determinism contract.
    pub workers: usize,
    /// Steady-state simulation fast path on each shard's cluster
    /// ([`crate::sim::fastpath`]); bit-exact, `false` is the escape
    /// hatch (`serve-bench --no-fastpath`).
    pub fastpath: bool,
    /// Re-simulate every fast-path replay and panic on divergence (soak
    /// tests; implies heavy slowdown; no-op without `fastpath`).
    pub crosscheck: bool,
    /// Core timing tier of every shard cluster
    /// ([`crate::sim::CoreFidelity`]). Functional results — and with
    /// them the whole determinism contract — are tier-independent;
    /// cycle counts (latencies, deadline misses, occupancy) are not.
    /// With `tuned`, a non-fast tier also makes the autotuner confirm
    /// each winner at that tier before accepting it.
    pub fidelity: CoreFidelity,
    /// Elastic shard pool: walk the active shard count between
    /// `min_shards` and `max_shards` from queue pressure and idleness
    /// ([`autoscale`]). `None` keeps all `shards` active (static fleet).
    pub autoscale: Option<AutoscaleConfig>,
    /// Autotuned deployments: on the first dispatch of a model, run the
    /// simulator-in-the-loop tuner ([`crate::dory::autotune`]) and
    /// compile the plan with [`deploy_tuned`] instead of [`deploy`].
    /// Tuning is deterministic and cached fleet-wide (once per model,
    /// like the plan cache), so this changes measured per-layer plans —
    /// never outputs, and never determinism (`serve-bench --tuned`).
    pub tuned: bool,
    /// Retain a clone of every dispatched request until its simulated
    /// completion cycle passes, so a shard failure can retract and
    /// re-queue exactly the work it was running
    /// ([`Engine::fail_shard`]). Off by default: single-engine paths
    /// never fail shards and the clones cost memory. The [`federation`]
    /// layer turns it on.
    pub track_inflight: bool,
    /// Fleet power cap [mW]: the dispatch-time budget for the sum of
    /// conservative busy-power bounds
    /// ([`EnergyModel::busy_power_bound_mw`]) over concurrently busy
    /// shards. The governor downgrades operating points, then skips
    /// dispatch, to stay under it; one busy shard is always allowed
    /// (`serve-bench --power-cap`). `None` = uncapped.
    pub power_cap_mw: Option<f64>,
    /// Operating-point selection policy of the DVFS governor
    /// ([`crate::power::DvfsPolicy`]; `serve-bench --dvfs`). The
    /// default pins the nominal point, which leaves every cycle number
    /// exactly as a pre-DVFS fleet reported it.
    pub dvfs: DvfsPolicy,
    pub isa: IsaVariant,
    pub budget: MemBudget,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 4,
            n_cores: crate::CLUSTER_CORES,
            queue_capacity: 64,
            max_batch: 8,
            prefer_resident: true,
            exact: false,
            workers: 0,
            fastpath: true,
            crosscheck: false,
            fidelity: CoreFidelity::Fast,
            autoscale: None,
            tuned: false,
            track_inflight: false,
            power_cap_mw: None,
            dvfs: DvfsPolicy::default(),
            isa: IsaVariant::FlexV,
            budget: MemBudget::default(),
        }
    }
}

/// One event of an arrival trace.
pub struct TraceItem {
    /// Arrival time in simulated cycles.
    pub at: u64,
    /// Index into the engine's model registry.
    pub model: usize,
    /// SLO class index (into the engine's class table; 0 = default).
    pub class: u8,
    pub priority: u8,
    /// Absolute deadline cycle (`None` = best-effort).
    pub deadline: Option<u64>,
    pub input: QTensor,
}

struct ModelEntry {
    name: String,
    net: Network,
    key: PlanKey,
}

/// One shard's work for a dispatch round: formed sequentially (so queue
/// decisions stay deterministic), executed possibly in parallel.
struct Assignment {
    shard: usize,
    model: usize,
    key: PlanKey,
    dep: Arc<Deployment>,
    batch: Vec<Request>,
    /// Operating-point index the governor chose for this batch.
    op: u8,
}

/// One dispatched request awaiting its simulated completion cycle —
/// retained so a shard failure can retract and re-queue exactly the
/// work the shard was running ([`Engine::fail_shard`]). Only populated
/// under [`ServeConfig::track_inflight`].
struct Inflight {
    finish: u64,
    req: Request,
}

/// The serving engine: model registry + queue + batcher + shard pool +
/// plan cache (+ optional autoscaler), advanced by a deterministic
/// discrete-event loop.
pub struct Engine {
    pub cfg: ServeConfig,
    models: Vec<ModelEntry>,
    pub cache: PlanCache,
    /// Per-model tunings (populated lazily when `cfg.tuned`), keyed by
    /// the same [`PlanKey`] as the plan cache so both agree on model
    /// identity.
    tune: TuneCache,
    pub queue: RequestQueue,
    shards: Vec<Shard>,
    scaler: Option<Autoscaler>,
    /// SLO class table for per-class metrics (index = `Request::class`).
    classes: Vec<SloClass>,
    em: EnergyModel,
    completions: Vec<Completion>,
    /// Shed-before-simulate events, in decision order.
    shed_log: Vec<ShedEvent>,
    /// `(cycle, active shard count)` — one entry at start plus one per
    /// scaling action.
    occupancy: Vec<(u64, usize)>,
    /// Minimum observed exec cycles per model (0 = never served): the
    /// deterministic lower bound the shed decision uses.
    min_exec: Vec<u64>,
    /// Dispatched-but-not-yet-finished requests (failover retraction
    /// pool); empty unless [`ServeConfig::track_inflight`].
    inflight: Vec<Inflight>,
    /// Operating point each shard last ran at (transition detection).
    shard_op: Vec<u8>,
    /// Busy-power bound [mW] of each shard's last dispatched batch —
    /// counted against the cap while `busy_until > now`.
    shard_power: Vec<f64>,
    /// DVFS transition log: `(cycle, shard, from, to)` operating-point
    /// indices, in decision order (trace instants + metrics).
    dvfs_log: Vec<(u64, usize, u8, u8)>,
    next_id: u64,
}

/// Priority → operating-point tier of the [`DvfsPolicy::Slo`] policy
/// (must agree with the `Slo` arm of the governor's preferred-point
/// selection; also the batcher's tier filter under that policy).
fn slo_tier(priority: u8) -> usize {
    match priority {
        0 => OP_EFFICIENCY,
        1 => OP_NOMINAL,
        _ => OP_BOOST,
    }
}

impl Engine {
    pub fn new(cfg: ServeConfig) -> Self {
        assert!(cfg.shards >= 1, "need at least one shard");
        // One window cache for the whole fleet: shard B replays windows
        // shard A recorded (wall-clock only; replay is bit-exact).
        let windows = crate::sim::fastpath::WindowCache::default();
        let mut shards: Vec<Shard> = (0..cfg.shards)
            .map(|i| {
                let mut s = Shard::new(
                    i,
                    cfg.n_cores,
                    cfg.exact,
                    cfg.fastpath.then(|| windows.clone()),
                    cfg.fidelity,
                );
                if cfg.crosscheck {
                    s.set_crosscheck(true);
                }
                s
            })
            .collect();
        let scaler = cfg.autoscale.map(|ac| {
            assert!(
                ac.min_shards >= 1 && ac.min_shards <= ac.max_shards,
                "autoscale needs 1 <= min <= max"
            );
            // Start at the floor: the ramp to peak is the autoscaler's job.
            for s in shards.iter_mut().skip(ac.min_shards) {
                s.park();
            }
            Autoscaler::new(ac)
        });
        let active = shards.iter().filter(|s| s.active).count();
        Engine {
            models: Vec::new(),
            cache: PlanCache::new(),
            tune: TuneCache::new(),
            queue: RequestQueue::new(cfg.queue_capacity),
            shards,
            scaler,
            classes: SloClass::best_effort(),
            em: EnergyModel::default(),
            completions: Vec::new(),
            shed_log: Vec::new(),
            occupancy: vec![(0, active)],
            min_exec: Vec::new(),
            inflight: Vec::new(),
            shard_op: vec![OP_NOMINAL as u8; cfg.shards],
            shard_power: vec![0.0; cfg.shards],
            dvfs_log: Vec::new(),
            next_id: 0,
            cfg,
        }
    }

    /// Register a model; returns its registry index. The plan itself is
    /// compiled lazily (and cached) on first dispatch.
    pub fn register(&mut self, net: Network) -> usize {
        net.validate().expect("invalid network");
        let key = PlanKey::for_network(&net, self.cfg.isa, self.cfg.budget, self.cfg.n_cores);
        self.models.push(ModelEntry { name: net.name.clone(), net, key });
        self.min_exec.push(0);
        self.models.len() - 1
    }

    pub fn model_count(&self) -> usize {
        self.models.len()
    }

    pub fn model_name(&self, model: usize) -> &str {
        &self.models[model].name
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Requests shed because their deadline became unmeetable, in
    /// decision order (part of the deterministic event stream).
    pub fn shed_events(&self) -> &[ShedEvent] {
        &self.shed_log
    }

    /// DVFS transition log: `(cycle, shard, from, to)` operating-point
    /// indices, in decision order (part of the deterministic event
    /// stream; empty while the governor pins one point).
    pub fn dvfs_log(&self) -> &[(u64, usize, u8, u8)] {
        &self.dvfs_log
    }

    /// The fleet's autotune cache (empty unless `cfg.tuned`); tunings
    /// are keyed by the same [`PlanKey`] as the plan cache.
    pub fn tuning(&self) -> &TuneCache {
        &self.tune
    }

    /// Shard-occupancy timeline: `(cycle, active shards)` at start and
    /// after every scaling action.
    pub fn occupancy(&self) -> &[(u64, usize)] {
        &self.occupancy
    }

    /// The installed SLO class table (trace builders resolve class
    /// names from it).
    pub fn classes(&self) -> &[SloClass] {
        &self.classes
    }

    /// Build the fleet timeline as a canonicalized trace recorder
    /// (export with [`crate::trace::chrome::to_chrome_json`], or the
    /// CLI's `serve-bench --trace-out`).
    ///
    /// The timeline is reconstructed **post hoc** from the engine's
    /// deterministic records (completions, sheds, occupancy) — shard
    /// worker threads never touch a sink, so tracing cannot perturb
    /// scheduling, and the export is byte-identical across
    /// [`ServeConfig::workers`] and [`ServeConfig::fastpath`] settings
    /// (gated by `rust/tests/trace_determinism.rs` and CI). Track layout
    /// is documented in [`crate::trace::serve`].
    pub fn build_trace(&self) -> crate::trace::Recorder {
        use crate::trace::serve::{build_fleet_trace, FleetTraceInputs};
        let names: Vec<String> = self.models.iter().map(|m| m.name.clone()).collect();
        let mut rec = build_fleet_trace(&FleetTraceInputs {
            completions: &self.completions,
            shed: &self.shed_log,
            occupancy: &self.occupancy,
            model_names: &names,
            classes: &self.classes,
            shards: self.shards.len(),
            plan_cache: (self.cache.hits, self.cache.misses),
            tune_cache: (self.tune.hits, self.tune.misses),
            dvfs: &self.dvfs_log,
        });
        rec.canonicalize();
        rec
    }

    /// Install the SLO class table used for per-class metrics (index =
    /// `Request::class`/`TraceItem::class`). [`Engine::workload_trace`]
    /// does this automatically.
    pub fn set_classes(&mut self, classes: Vec<SloClass>) {
        assert!(!classes.is_empty() && classes.len() <= 256, "1..=256 classes");
        self.classes = classes;
    }

    /// Generate a deterministic arrival trace from `spec` over the
    /// registered models, and install `spec.classes` as the engine's
    /// class table (so the fleet report breaks latency/miss/shed stats
    /// out per class).
    pub fn workload_trace(&mut self, spec: &WorkloadSpec) -> Vec<TraceItem> {
        assert_eq!(spec.mix.len(), self.models.len(), "one mix weight per model");
        self.set_classes(spec.classes.clone());
        let io: Vec<(Vec<usize>, u8)> = self
            .models
            .iter()
            .map(|m| (m.net.input_shape.to_vec(), m.net.input_bits))
            .collect();
        workload::generate(spec, &io)
    }

    /// Enqueue one request. Returns the request id, or `None` if the
    /// queue rejected it (saturation).
    pub fn submit(&mut self, t: TraceItem) -> Option<u64> {
        let entry = &self.models[t.model];
        assert_eq!(
            t.input.shape,
            entry.net.input_shape.to_vec(),
            "input shape mismatch for model {}",
            entry.name
        );
        assert_eq!(t.input.bits, entry.net.input_bits, "input bits mismatch");
        assert!((t.class as usize) < self.classes.len(), "unknown SLO class {}", t.class);
        let id = self.next_id;
        let admitted = self.queue.push(Request {
            id,
            model: t.model,
            class: t.class,
            priority: t.priority,
            arrival_cycle: t.at,
            deadline: t.deadline,
            input: t.input,
        });
        if admitted {
            self.next_id += 1;
            Some(id)
        } else {
            None
        }
    }

    /// Shed-before-simulate: drop every queued request that can no
    /// longer meet its deadline, using the minimum observed execution
    /// time of its model as the (deterministic) remaining-cost lower
    /// bound. Runs on the engine thread before each dispatch round.
    fn shed_unmeetable(&mut self, now: u64) {
        if self.queue.is_empty() {
            return;
        }
        let min_exec = &self.min_exec;
        let shed = self.queue.shed_expired(now, |m| min_exec[m]);
        for r in shed {
            self.shed_log.push(ShedEvent {
                id: r.id,
                model: r.model,
                class: r.class,
                priority: r.priority,
                arrival_cycle: r.arrival_cycle,
                deadline: r.deadline.expect("only deadlined requests are shed"),
                shed_cycle: now,
            });
        }
    }

    /// Conservative busy-power bound [mW] of one shard at operating
    /// point `idx` (the governor's per-shard cost against the cap).
    fn shard_bound_mw(&self, idx: usize) -> f64 {
        let op = operating_points(self.cfg.isa)[idx];
        self.em.busy_power_bound_mw(self.cfg.isa, self.cfg.n_cores, &op)
    }

    /// How many shards the power cap can fund at the lowest operating
    /// point — the autoscaler's ceiling (never below 1: one shard always
    /// serves). `None` without a cap.
    fn cap_max_active(&self) -> Option<usize> {
        self.cfg
            .power_cap_mw
            .map(|cap| ((cap / self.shard_bound_mw(OP_EFFICIENCY)).floor() as usize).max(1))
    }

    /// The DVFS policy's preferred operating point for a batch led by a
    /// request of `lead_priority` (before throttle and cap clamps).
    fn preferred_op(&self, lead_priority: u8) -> usize {
        match self.cfg.dvfs {
            DvfsPolicy::RaceToIdle => OP_BOOST,
            DvfsPolicy::SlowAndSteady => OP_EFFICIENCY,
            DvfsPolicy::Slo => slo_tier(lead_priority),
            DvfsPolicy::Fixed(idx) => idx.min(OP_EFFICIENCY),
        }
    }

    /// One autoscaler step between dispatch rounds (no-op for a static
    /// fleet). Decisions see the post-shed queue depth, clamped to the
    /// shard count the power cap can fund.
    fn autoscale_step(&mut self, now: u64) {
        let max_active = self.cap_max_active();
        let Some(scaler) = self.scaler.as_mut() else {
            return;
        };
        if scaler.step(now, self.queue.len(), &mut self.shards, max_active).is_some() {
            let active = self.shards.iter().filter(|s| s.active).count();
            self.occupancy.push((now, active));
        }
    }

    /// Hand batches to every free, active shard.
    ///
    /// Batch **formation** (queue pops, plan-cache lookups, shard
    /// assignment) runs sequentially in shard order, so every scheduling
    /// decision is deterministic. The formed batches are independent
    /// single-shard simulations; with `cfg.workers != 1` they **execute**
    /// on a scoped thread pool. Either way the round's completion events
    /// go through the same reduction — merged by simulated finish cycle,
    /// tie-break (shard id, request id) — so the completion stream is
    /// bit-identical for any worker count.
    /// DVFS and the power cap are part of the sequential half: the
    /// operating point of every batch is chosen here from simulated state
    /// only (queue, shard busy-power bounds, the fault plan's throttle
    /// windows), so energy numbers and the completion stream stay
    /// bit-identical for any worker count.
    fn dispatch_free_shards(&mut self, now: u64) {
        let policy = BatchPolicy {
            max_batch: self.cfg.max_batch,
            prefer_resident: self.cfg.prefer_resident,
            tier_of: matches!(self.cfg.dvfs, DvfsPolicy::Slo)
                .then_some(slo_tier as fn(u8) -> usize),
        };
        let cap = self.cfg.power_cap_mw;
        // Busy-power committed by shards still executing a prior batch.
        let mut inflight_mw: f64 = self
            .shards
            .iter()
            .filter(|s| s.busy_until > now)
            .map(|s| self.shard_power[s.id])
            .sum();
        let floor_mw = self.shard_bound_mw(OP_EFFICIENCY);
        let mut assignments: Vec<Assignment> = Vec::new();
        for si in 0..self.shards.len() {
            if !self.shards[si].active || !self.shards[si].is_free(now) {
                continue;
            }
            if self.queue.is_empty() {
                break;
            }
            // Admission: skip this shard when even the efficiency point
            // would breach the cap. The floor `inflight_mw > 0` keeps one
            // shard always eligible (no deadlock under a sub-shard cap),
            // and a skip implies a busy shard exists, so the event loop
            // has a wake-up and re-tries at its finish (no livelock).
            if let Some(cap) = cap {
                if inflight_mw > 0.0 && inflight_mw + floor_mw > cap {
                    continue;
                }
            }
            let resident = self.shards[si].resident_model;
            let Some(batch) = batcher::next_batch(&mut self.queue, resident, &policy) else {
                break;
            };
            let model = batch[0].model;
            let lead_priority = batch[0].priority;
            let (key, dep) = {
                let entry = &self.models[model];
                let (isa, budget, n_cores) = (self.cfg.isa, self.cfg.budget, self.cfg.n_cores);
                let dep = if self.cfg.tuned {
                    // Tune once per model (deterministic, cached
                    // fleet-wide), then compile the tuned plan once.
                    // The search measures on the fast tier; a non-fast
                    // fleet re-confirms each winner at its own tier.
                    let tune_cfg = TuneConfig {
                        confirm_fidelity: (self.cfg.fidelity != CoreFidelity::Fast)
                            .then_some(self.cfg.fidelity),
                        ..TuneConfig::default()
                    };
                    let tuning = self.tune.get_or_tune(entry.key, || {
                        autotune::tune_network(&entry.net, isa, budget, n_cores, &tune_cfg)
                    });
                    self.cache
                        .get_or_build(entry.key, || deploy_tuned(&entry.net, isa, budget, tuning))
                } else {
                    self.cache.get_or_build(entry.key, || deploy(&entry.net, isa, budget))
                };
                (entry.key, dep)
            };
            // Governor: policy preference, clamped by an active thermal
            // throttle, then downgraded until the batch fits the cap.
            let mut op = self.preferred_op(lead_priority);
            if self.shards[si].is_throttled(now) {
                op = OP_EFFICIENCY;
            }
            if let Some(cap) = cap {
                while op < OP_EFFICIENCY && inflight_mw + self.shard_bound_mw(op) > cap {
                    op += 1;
                }
            }
            let bound = self.shard_bound_mw(op);
            inflight_mw += bound;
            self.shard_power[si] = bound;
            if self.shard_op[si] != op as u8 {
                self.dvfs_log.push((now, si, self.shard_op[si], op as u8));
                self.shard_op[si] = op as u8;
            }
            assignments.push(Assignment { shard: si, model, key, dep, batch, op: op as u8 });
        }
        if assignments.is_empty() {
            return;
        }
        // Failover retraction pool: clone dispatched requests before
        // execution consumes them (inputs are needed to re-run).
        let mut pending: Vec<Request> = Vec::new();
        if self.cfg.track_inflight {
            for a in &assignments {
                pending.extend(a.batch.iter().cloned());
            }
        }
        let em = self.em;
        let workers = if self.cfg.workers == 0 { assignments.len() } else { self.cfg.workers };
        let mut round: Vec<Completion> = Vec::new();
        if workers <= 1 || assignments.len() == 1 {
            for a in assignments {
                round.extend(
                    self.shards[a.shard].run_batch(a.model, a.key, &a.dep, a.batch, now, &em, a.op),
                );
            }
        } else {
            let mut assignments = assignments;
            while !assignments.is_empty() {
                let rest = assignments.split_off(workers.min(assignments.len()));
                let chunk = std::mem::replace(&mut assignments, rest);
                let shards = &mut self.shards;
                let results: Vec<Vec<Completion>> = std::thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(chunk.len());
                    // Shard indices are strictly increasing, so the pool
                    // splits into disjoint mutable borrows.
                    let mut tail: &mut [Shard] = &mut shards[..];
                    let mut consumed = 0usize;
                    for a in chunk {
                        let (_, at) = tail.split_at_mut(a.shard - consumed);
                        let (one, rest) = at.split_at_mut(1);
                        consumed = a.shard + 1;
                        tail = rest;
                        let shard = &mut one[0];
                        let em = &em;
                        handles.push(scope.spawn(move || {
                            shard.run_batch(a.model, a.key, &a.dep, a.batch, now, em, a.op)
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("shard worker panicked"))
                        .collect()
                });
                for comps in results {
                    round.extend(comps);
                }
            }
        }
        // Deterministic event-ordering reduction (see module docs).
        round.sort_by_key(|c| (c.finish_cycle, c.shard, c.id));
        for c in &round {
            let m = &mut self.min_exec[c.model];
            if *m == 0 || c.exec_cycles < *m {
                *m = c.exec_cycles;
            }
        }
        if self.cfg.track_inflight {
            for c in &round {
                let pos = pending
                    .iter()
                    .position(|r| r.id == c.id)
                    .expect("every completion comes from this round's batches");
                let req = pending.swap_remove(pos);
                self.inflight.push(Inflight { finish: c.finish_cycle, req });
            }
        }
        self.completions.extend(round);
    }

    /// One engine step at simulated cycle `now`: shed unmeetable
    /// requests, adjust the elastic pool, and dispatch batches to free
    /// shards. [`Engine::run_trace`] is this plus the event-driven
    /// clock; external drivers (the [`federation`] event loop) call it
    /// directly so faults and rollouts can interleave between steps.
    pub fn pump(&mut self, now: u64) {
        if self.cfg.track_inflight {
            self.inflight.retain(|f| f.finish > now);
        }
        self.shed_unmeetable(now);
        self.autoscale_step(now);
        self.dispatch_free_shards(now);
    }

    /// Earliest future cycle at which another [`Engine::pump`] could
    /// make progress: the next shard-free event while work is queued,
    /// or the next scale-down-eligibility event while idle (clamped to
    /// `now`; see `run_trace`). `None` when nothing is pending — the
    /// engine is drained (external arrivals aside).
    pub fn next_wake(&self, now: u64) -> Option<u64> {
        if self.queue.is_empty() {
            self.scaler
                .as_ref()
                .and_then(|sc| sc.next_down_event(&self.shards))
                .map(|t| t.max(now))
        } else {
            self.shards
                .iter()
                .filter(|s| s.active)
                .map(|s| s.busy_until)
                .filter(|&b| b > now)
                .min()
        }
    }

    /// Whether the engine has no queued or executing work at `now` —
    /// drain complete (the rollout controller's switch gate).
    pub fn is_idle(&self, now: u64) -> bool {
        self.queue.is_empty() && self.shards.iter().all(|s| s.busy_until <= now)
    }

    /// Fault-inject: take `shard` down at cycle `now`, until `until`.
    ///
    /// Completions the shard would have produced after `now` are
    /// retracted and their requests re-queued with original priority,
    /// deadline, and arrival cycle ([`RequestQueue::requeue`]) — so
    /// failover never drops admitted work and re-serves it in exactly
    /// the order its SLO earns. Retraction happens in completion-stream
    /// order (deterministic). The shard's timing bookkeeping rolls back
    /// to `now` and it parks until [`Engine::recover_shard`]; the
    /// autoscaler will not wake it while failed. Requires
    /// [`ServeConfig::track_inflight`] (the engine otherwise does not
    /// retain dispatched inputs).
    pub fn fail_shard(&mut self, shard: usize, now: u64, until: u64) {
        assert!(
            self.cfg.track_inflight,
            "fail_shard requires ServeConfig::track_inflight"
        );
        let retracted: Vec<u64> = self
            .completions
            .iter()
            .filter(|c| c.shard == shard && c.finish_cycle > now)
            .map(|c| c.id)
            .collect();
        self.completions.retain(|c| !(c.shard == shard && c.finish_cycle > now));
        let s = &mut self.shards[shard];
        s.served -= retracted.len() as u64;
        if s.busy_until > now {
            // Dispatch only ever starts a batch at or before the fault
            // cycle, so the rollback window is `now..busy_until`.
            s.busy_cycles -= s.busy_until - now;
            s.busy_until = now;
        }
        s.fail(until);
        for id in retracted {
            let pos = self
                .inflight
                .iter()
                .position(|f| f.req.id == id)
                .expect("retracted completion has an in-flight record");
            let f = self.inflight.swap_remove(pos);
            self.queue.requeue(f.req);
        }
        let active = self.shards.iter().filter(|s| s.active).count();
        self.occupancy.push((now, active));
    }

    /// Recover a failed shard at `now`: healthy and active again, cold
    /// (the model image did not survive the failure).
    pub fn recover_shard(&mut self, shard: usize, now: u64) {
        self.shards[shard].recover();
        let active = self.shards.iter().filter(|s| s.active).count();
        self.occupancy.push((now, active));
    }

    /// Straggler-inject: batches starting on `shard` before `until` run
    /// `factor`× slower (timing overlay only; see [`Shard::slow`]).
    pub fn slow_shard(&mut self, shard: usize, factor: u64, until: u64) {
        self.shards[shard].slow(factor, until);
    }

    /// Thermal-throttle inject: batches starting on `shard` before
    /// `until` are clamped to the efficiency operating point regardless
    /// of DVFS policy (the governor's clamp in
    /// [`Engine::dispatch_free_shards`]; see [`Shard::throttle`]).
    pub fn throttle_shard(&mut self, shard: usize, until: u64) {
        self.shards[shard].throttle(until);
    }

    /// Flip the engine's deployment mode (live rollout: the canary
    /// switches to tuned plans). Affects models compiled after the
    /// call; already-cached plans win on their [`PlanKey`], which is
    /// exactly why rollouts install warm caches first
    /// ([`Engine::warm_caches`]).
    pub fn set_tuned(&mut self, tuned: bool) {
        self.cfg.tuned = tuned;
    }

    /// Warm-migrate compiled plans and tunings from caches built
    /// off-path (live rollout: the controller compiles the new version
    /// outside the serving loop, then installs it without a cold
    /// start). Entries overwrite same-key entries — tuned and default
    /// deployments share a [`PlanKey`], so installing tuned plans over
    /// the defaults *is* the version switch.
    pub fn warm_caches(&mut self, plans: &PlanCache, tunes: &TuneCache) {
        self.cache.warm_from(plans);
        self.tune.warm_from(tunes);
    }

    /// A registered model's network and plan identity (rollout
    /// controllers compile new versions off-path).
    pub fn model_entry(&self, model: usize) -> (&Network, PlanKey) {
        let m = &self.models[model];
        (&m.net, m.key)
    }

    /// Replay an arrival trace to completion; returns the fleet report.
    /// The event loop advances a simulated clock: arrivals are admitted
    /// when due, unmeetable requests are shed, the autoscaler adjusts
    /// the active pool, free shards pull batches, and time jumps to the
    /// next arrival, shard-free, or scale-down-eligibility event —
    /// O(events), independent of idle gaps.
    pub fn run_trace(&mut self, mut trace: Vec<TraceItem>) -> FleetMetrics {
        trace.sort_by_key(|t| t.at);
        let mut it = trace.into_iter().peekable();
        let mut clock = 0u64;
        loop {
            while it.peek().map_or(false, |t| t.at <= clock) {
                let t = it.next().unwrap();
                self.submit(t);
            }
            self.pump(clock);
            // Jump to the next event. With work queued, `next_wake` is
            // the next shard-free cycle (every active shard is busy —
            // dispatch drains otherwise). With the queue empty, it is
            // the next cycle at which the autoscaler could park an idle
            // shard, so valleys between bursts actually shrink the
            // fleet instead of being skipped by the jump; it may be
            // `<= clock` (zero cooldown right after a park, or
            // eligibility reached while the queue was still non-empty),
            // clamped to `clock` so the loop re-enters at the same
            // cycle and parks the next shard — each such pass shrinks
            // the pool, so this always terminates.
            let next_arrival = it.peek().map(|t| t.at);
            clock = match (next_arrival, self.next_wake(clock)) {
                (Some(a), Some(w)) => a.min(w),
                (Some(a), None) => a,
                (None, Some(w)) => w,
                (None, None) => break,
            };
        }
        self.metrics()
    }

    /// Build the fleet report from everything served so far.
    pub fn metrics(&self) -> FleetMetrics {
        let names: Vec<String> = self.models.iter().map(|m| m.name.clone()).collect();
        // Tuned-vs-default measured cycle deltas of every model the
        // autotuner has processed (the tuner's own per-layer metric).
        let mut tuned = metrics::TunedSummary::default();
        for m in &self.models {
            if let Some(t) = self.tune.get(m.key) {
                tuned.models += 1;
                tuned.default_cycles += t.total_default_cycles();
                tuned.tuned_cycles += t.total_tuned_cycles();
                tuned.improved_layers += t.improved_layers();
            }
        }
        FleetMetrics::collect(metrics::CollectInputs {
            completions: &self.completions,
            names: &names,
            classes: &self.classes,
            queue: &self.queue,
            cache: &self.cache,
            shards: &self.shards,
            shed: &self.shed_log,
            occupancy: &self.occupancy,
            scaler: self.scaler.as_ref(),
            tuned,
            dvfs_transitions: self.dvfs_log.len() as u64,
            power_cap_mw: self.cfg.power_cap_mw,
        })
    }

    /// Deterministic synthetic traffic: `n` best-effort requests with
    /// uniform random inter-arrival gaps (mean `mean_gap_cycles`),
    /// models drawn from `mix` (one non-negative weight per registered
    /// model), inputs random per request. The legacy pre-[`workload`]
    /// generator, kept for the default `serve-bench` path.
    pub fn synthetic_trace(
        &self,
        n: usize,
        mean_gap_cycles: u64,
        mix: &[f64],
        seed: u64,
    ) -> Vec<TraceItem> {
        assert_eq!(mix.len(), self.models.len(), "one mix weight per model");
        let total: f64 = mix.iter().sum();
        assert!(total > 0.0, "mix must have positive mass");
        let mut rng = Prng::new(seed);
        let mut at = 0u64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            at += rng.below(mean_gap_cycles.max(1) * 2);
            let model = workload::weighted_pick(&mut rng, mix);
            let net = &self.models[model].net;
            out.push(TraceItem {
                at,
                model,
                class: 0,
                priority: 0,
                deadline: None,
                input: QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng),
            });
        }
        out
    }
}

/// The paper's three evaluation networks (MobileNetV1-8b, -8b4b at
/// `input_hw`, ResNet-20-4b2b) — the standard serving mix used by the
/// `serve-bench` subcommand and the throughput bench.
pub fn standard_mix(input_hw: usize) -> Vec<Network> {
    crate::models::MODEL_NAMES
        .iter()
        .map(|n| crate::models::by_name(n, input_hw).expect("known model"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::Layer;

    fn tiny(name: &str, seed: u64) -> Network {
        let mut rng = Prng::new(seed);
        let mut net = Network::new(name, [8, 8, 8], 8);
        net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net
    }

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            shards: 2,
            n_cores: 4,
            queue_capacity: 32,
            max_batch: 4,
            ..ServeConfig::default()
        }
    }

    fn item(at: u64, model: usize, priority: u8, input: QTensor) -> TraceItem {
        TraceItem { at, model, class: 0, priority, deadline: None, input }
    }

    #[test]
    fn fleet_serves_mixed_traffic_with_cache_and_batching() {
        let mut eng = Engine::new(small_cfg());
        let a = eng.register(tiny("net-a", 1));
        let b = eng.register(tiny("net-b", 2));
        let mut rng = Prng::new(3);
        let mut trace = Vec::new();
        for (i, m) in [a, a, b, a, b, a, b, b].into_iter().enumerate() {
            trace.push(item(
                i as u64 * 100,
                m,
                0,
                QTensor::random(&[8, 8, 8], 8, false, &mut rng),
            ));
        }
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 8);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.shed, 0);
        assert_eq!(m.deadline_misses, 0);
        // deploy ran once per model, later dispatches hit the cache
        assert_eq!(m.cache_misses, 2);
        assert!(m.cache_hits >= 1, "hits {}", m.cache_hits);
        assert_eq!(m.cache_entries, 2);
        assert!(m.p50_cycles > 0 && m.p99_cycles >= m.p50_cycles);
        assert!(m.aggregate_macs_per_cycle > 0.0);
        assert_eq!(m.rows.len(), 2);
        assert_eq!(m.rows[0].served + m.rows[1].served, 8);
        // a static fleet's occupancy is flat at `shards`
        assert_eq!(m.occupancy, vec![(0, 2)]);
        // every request completed exactly once
        let mut ids: Vec<u64> = eng.completions().iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let rendered = m.render();
        assert!(rendered.contains("net-a") && rendered.contains("plan cache"));
    }

    #[test]
    fn saturation_rejects_beyond_queue_capacity() {
        let cfg = ServeConfig { queue_capacity: 2, shards: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("sat", 4));
        let mut rng = Prng::new(5);
        let trace: Vec<TraceItem> = (0..6)
            .map(|_| item(0, a, 0, QTensor::random(&[8, 8, 8], 8, false, &mut rng)))
            .collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 2);
        assert_eq!(m.rejected, 4);
        assert_eq!(m.peak_queue_depth, 2);
    }

    #[test]
    fn priorities_jump_the_queue() {
        let cfg = ServeConfig { shards: 1, max_batch: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("lo", 6));
        let b = eng.register(tiny("hi", 7));
        let mut rng = Prng::new(8);
        let trace = vec![
            item(0, a, 0, QTensor::random(&[8, 8, 8], 8, false, &mut rng)),
            item(0, b, 2, QTensor::random(&[8, 8, 8], 8, false, &mut rng)),
        ];
        eng.run_trace(trace);
        assert_eq!(eng.completions()[0].model, b, "high priority first");
        assert_eq!(eng.completions()[1].model, a);
    }

    /// Worker count and fast-path setting change wall-clock time only:
    /// the completion stream and fleet metrics are bit-identical.
    #[test]
    fn worker_count_and_fastpath_do_not_change_results() {
        let run = |workers: usize, fastpath: bool| {
            let cfg = ServeConfig { workers, fastpath, ..small_cfg() };
            let mut eng = Engine::new(cfg);
            let a = eng.register(tiny("wk-a", 31));
            let b = eng.register(tiny("wk-b", 32));
            let mut rng = Prng::new(33);
            let trace: Vec<TraceItem> = (0..8)
                .map(|i| {
                    item(
                        i as u64 * 50,
                        if i % 3 == 0 { b } else { a },
                        (i % 2) as u8,
                        QTensor::random(&[8, 8, 8], 8, false, &mut rng),
                    )
                })
                .collect();
            let m = eng.run_trace(trace);
            let comps: Vec<(u64, usize, usize, u64, u64)> = eng
                .completions()
                .iter()
                .map(|c| (c.id, c.model, c.shard, c.start_cycle, c.finish_cycle))
                .collect();
            (m.span_cycles, m.p99_cycles, comps)
        };
        let base = run(1, false);
        assert_eq!(base, run(4, false), "threading changed results");
        assert_eq!(base, run(0, true), "fast path changed results");
        assert_eq!(base, run(2, true));
    }

    /// Tuned mode: the tuner runs once per model, the tuned plans carry
    /// exec overrides, the per-layer measured cost never regresses, and
    /// exact-mode outputs stay bit-identical to the untuned fleet.
    #[test]
    fn tuned_mode_tunes_once_and_keeps_outputs_bit_identical() {
        // inputs depend only on the seed, so both runs see the same trace
        let trace_for = |a: usize, b: usize| {
            let mut rng = Prng::new(40);
            (0..6)
                .map(|i| {
                    item(
                        i as u64 * 80,
                        if i % 2 == 0 { a } else { b },
                        0,
                        QTensor::random(&[8, 8, 8], 8, false, &mut rng),
                    )
                })
                .collect::<Vec<_>>()
        };
        let run = |tuned: bool| {
            let cfg = ServeConfig { tuned, exact: true, ..small_cfg() };
            let mut eng = Engine::new(cfg);
            let a = eng.register(tiny("tn-a", 38));
            let b = eng.register(tiny("tn-b", 39));
            let trace = trace_for(a, b);
            let m = eng.run_trace(trace);
            let mut outs: Vec<(u64, Vec<u8>)> =
                eng.completions().iter().map(|c| (c.id, c.output.clone())).collect();
            outs.sort();
            (m, outs, eng.tuning().len(), eng.tuning().misses)
        };
        let (mt, outs_t, tuned_entries, tuner_runs) = run(true);
        let (mu, outs_u, untuned_entries, _) = run(false);
        assert_eq!(tuned_entries, 2, "one tuning per model");
        assert_eq!(tuner_runs, 2, "tuner must run once per model, then cache");
        assert_eq!(untuned_entries, 0);
        assert_eq!(mt.tuned.models, 2);
        assert!(
            mt.tuned.tuned_cycles <= mt.tuned.default_cycles,
            "tuned measured cycles regressed: {:?}",
            mt.tuned
        );
        assert_eq!(mu.tuned, TunedSummary::default());
        assert_eq!(outs_t, outs_u, "tuning changed a model output");
        assert_eq!((mt.served, mu.served), (6, 6));
        // the tuned report carries the autotune line, the untuned not
        assert!(mt.render().contains("autotune:"), "{}", mt.render());
        assert!(!mu.render().contains("autotune:"));
    }

    /// The pipeline timing tier changes cycle numbers only: the served
    /// outputs are bit-identical to the fast tier, and no request
    /// executes in fewer cycles than it did there.
    #[test]
    fn pipeline_fidelity_changes_timing_never_outputs() {
        let run = |fidelity: CoreFidelity| {
            let cfg = ServeConfig { fidelity, exact: true, ..small_cfg() };
            let mut eng = Engine::new(cfg);
            let a = eng.register(tiny("fid-a", 50));
            let b = eng.register(tiny("fid-b", 51));
            let mut rng = Prng::new(52);
            let trace: Vec<TraceItem> = (0..6)
                .map(|i| {
                    item(
                        i as u64 * 70,
                        if i % 2 == 0 { a } else { b },
                        0,
                        QTensor::random(&[8, 8, 8], 8, false, &mut rng),
                    )
                })
                .collect();
            eng.run_trace(trace);
            let mut comps: Vec<(u64, Vec<u8>, u64)> = eng
                .completions()
                .iter()
                .map(|c| (c.id, c.output.clone(), c.exec_cycles))
                .collect();
            comps.sort();
            comps
        };
        let fast = run(CoreFidelity::Fast);
        let pipe = run(CoreFidelity::Pipeline);
        assert_eq!(fast.len(), pipe.len());
        for ((fid, fout, fcyc), (pid, pout, pcyc)) in fast.iter().zip(&pipe) {
            assert_eq!((fid, fout), (pid, pout), "fidelity changed an output");
            assert!(pcyc >= fcyc, "request {pid}: pipeline {pcyc} < fast {fcyc}");
        }
    }

    #[test]
    fn batching_amortizes_model_switches() {
        // one shard, two models, interleaved arrivals all queued up-front:
        // batching must group same-model requests, so switches < requests.
        let cfg = ServeConfig { shards: 1, max_batch: 8, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("m-a", 10));
        let b = eng.register(tiny("m-b", 11));
        let mut rng = Prng::new(12);
        let trace: Vec<TraceItem> = [a, b, a, b, a, b]
            .into_iter()
            .map(|m| item(0, m, 0, QTensor::random(&[8, 8, 8], 8, false, &mut rng)))
            .collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 6);
        assert!(
            m.model_switches <= 2,
            "batching should coalesce to one pass per model, got {} switches",
            m.model_switches
        );
        assert!(m.mean_batch >= 2.0, "mean batch {}", m.mean_batch);
    }

    /// An impossible deadline is shed before simulation (no shard ever
    /// runs it); a comfortable one is served and counted as met.
    #[test]
    fn unmeetable_deadlines_are_shed_not_simulated() {
        let cfg = ServeConfig { shards: 1, max_batch: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("slo", 13));
        eng.set_classes(vec![
            SloClass { name: "tight".into(), priority: 1, deadline_cycles: Some(1), share: 0.5 },
            SloClass { name: "easy".into(), priority: 0, deadline_cycles: None, share: 0.5 },
        ]);
        let mut rng = Prng::new(14);
        let mk = |at: u64, class: u8, deadline, rng: &mut Prng| TraceItem {
            at,
            model: a,
            class,
            priority: 1 - class,
            deadline,
            input: QTensor::random(&[8, 8, 8], 8, false, rng),
        };
        // Request 0 occupies the shard; request 1's deadline expires
        // while it waits (deadline 1 cycle after a later arrival).
        let trace = vec![
            mk(0, 1, None, &mut rng),
            mk(10, 0, Some(11), &mut rng),
            mk(20, 1, None, &mut rng),
        ];
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 2, "the expired request must not be simulated");
        assert_eq!(m.shed, 1);
        assert_eq!(eng.shed_events().len(), 1);
        assert_eq!(eng.shed_events()[0].id, 1);
        assert_eq!(eng.shed_events()[0].class, 0);
        assert_eq!(m.deadline_misses, 0, "sheds are not misses");
        assert!(eng.completions().iter().all(|c| c.id != 1));
        // per-class accounting: class 0 shed once, class 1 served twice
        assert_eq!(m.class_rows.len(), 2);
        assert_eq!(m.class_rows[0].shed, 1);
        assert_eq!(m.class_rows[0].served, 0);
        assert_eq!(m.class_rows[1].served, 2);
    }

    /// Deadlines that pass while a request executes are misses, not
    /// sheds: shedding only ever happens before simulation.
    #[test]
    fn late_completions_count_as_deadline_misses() {
        let cfg = ServeConfig { shards: 1, max_batch: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("miss", 15));
        eng.set_classes(vec![SloClass {
            name: "tight".into(),
            priority: 0,
            deadline_cycles: Some(2),
            share: 1.0,
        }]);
        let mut rng = Prng::new(16);
        let trace = vec![TraceItem {
            at: 0,
            model: a,
            class: 0,
            priority: 0,
            deadline: Some(2), // arrives meetable (min_exec unknown), finishes late
            input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
        }];
        let m = eng.run_trace(trace);
        assert_eq!((m.served, m.shed), (1, 0));
        assert_eq!(m.deadline_misses, 1);
        assert!(m.miss_rate() > 0.99);
        assert!(eng.completions()[0].missed_deadline());
    }

    /// The autoscaler wakes shards under backlog and parks them when the
    /// valley is long enough; the occupancy timeline records each step.
    #[test]
    fn autoscaler_tracks_load_and_charges_cold_start() {
        let mut auto_cfg = AutoscaleConfig::range(1, 2);
        auto_cfg.idle_cycles_down = 50_000;
        auto_cfg.cooldown_cycles = 0;
        let cfg = ServeConfig {
            shards: 2,
            max_batch: 1,
            autoscale: Some(auto_cfg),
            ..small_cfg()
        };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("elastic", 17));
        let mut rng = Prng::new(18);
        // burst of 4 at t=0, then a long valley, then one more request
        let mut trace: Vec<TraceItem> = (0..4)
            .map(|_| item(0, a, 0, QTensor::random(&[8, 8, 8], 8, false, &mut rng)))
            .collect();
        trace.push(item(
            100_000_000,
            a,
            0,
            QTensor::random(&[8, 8, 8], 8, false, &mut rng),
        ));
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 5);
        assert!(m.scale_ups >= 1, "burst must wake shard 1");
        assert!(m.scale_downs >= 1, "valley must park it again");
        let occ = eng.occupancy();
        assert_eq!(occ[0], (0, 1), "fleet starts at min");
        assert!(occ.iter().any(|&(_, n)| n == 2), "peaked at max");
        assert_eq!(occ.last().unwrap().1, 1, "back to min after the valley");
        // shard 1 served work during the burst; exactly one shard (the
        // less recently busy one is parked first) survives the valley
        assert!(eng.completions().iter().any(|c| c.shard == 1));
        assert_eq!(eng.shards().iter().filter(|s| s.active).count(), 1);
    }

    /// A cap below two boost-point shards forces the race-to-idle
    /// governor down to the efficiency point and serializes dispatch —
    /// everything still completes, fleet average power respects the cap,
    /// and the downgrade shows up in the transition log.
    #[test]
    fn power_cap_serializes_dispatch_and_bounds_power() {
        let mut cfg = small_cfg();
        cfg.dvfs = DvfsPolicy::RaceToIdle;
        let cap = 1.5 * Engine::new(cfg).shard_bound_mw(OP_EFFICIENCY);
        cfg.power_cap_mw = Some(cap);
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("capped", 31));
        let mut rng = Prng::new(32);
        let trace: Vec<TraceItem> = (0..6)
            .map(|_| item(0, a, 0, QTensor::random(&[8, 8, 8], 8, false, &mut rng)))
            .collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 6);
        // 1.5× the efficiency bound funds exactly one shard at any point
        // (even boost), so every batch is clamped to efficiency only when
        // a second shard wants in — but race-to-idle on an otherwise idle
        // fleet may still boost the first batch. All ops must be legal.
        assert!(eng.completions().iter().all(|c| (c.op as usize) <= OP_EFFICIENCY));
        assert!(m.fleet_avg_power_mw <= cap, "avg {} > cap {}", m.fleet_avg_power_mw, cap);
        assert!(m.dvfs_transitions >= 1, "boost→downgrade must be logged");
        assert_eq!(m.power_cap_mw, Some(cap));
        assert!(m.total_energy_pj > 0.0 && m.fleet_tops_per_watt > 0.0);
        assert!(m.render().contains("fleet avg power"));
    }

    /// The `slo` policy maps priority tiers to operating points:
    /// best-effort rides the efficiency corner, interactive gets boost.
    #[test]
    fn slo_policy_assigns_operating_points_by_priority() {
        let cfg = ServeConfig { shards: 1, dvfs: DvfsPolicy::Slo, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("slo", 33));
        let mut rng = Prng::new(34);
        let trace: Vec<TraceItem> = (0u64..6)
            .map(|i| {
                item(i * 50, a, (i % 3) as u8, QTensor::random(&[8, 8, 8], 8, false, &mut rng))
            })
            .collect();
        let priorities: Vec<u8> = trace.iter().map(|t| t.priority).collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 6);
        for c in eng.completions() {
            let want = slo_tier(priorities[c.id as usize]) as u8;
            assert_eq!(c.op, want, "request {} priority {}", c.id, priorities[c.id as usize]);
        }
        assert!(m.total_energy_pj > 0.0);
    }
}
