//! Bench: ablations of the paper's design choices (DESIGN.md §7):
//!   1. Mac&Load on/off        (Flex-V vs MPIC inner loop, same formats)
//!   2. NN-RF 4x4 vs 4x2       (Flex-V vs XpulpNN-style blocking, uniform)
//!   3. TCDM banking           (16 banks vs 8 vs 4: conflict sensitivity)
//!   4. hardware mixed support (Flex-V vs SW unpack on the same core)
//!
//! Pass `--artifact FILE` to also persist the `kernels` benchmark
//! artifact (the ablation cells are drawn from the same Table III /
//! Fig. 7 grid the `kernels` suite serializes).
//!
//!     cargo bench --bench ablation [-- --artifact BENCH_kernels.json]

use flexv::isa::IsaVariant;
use flexv::qnn::Precision;
use flexv::report::workloads::{conv_fig7_stats, matmul_table3_stats};

fn main() {
    println!("== Ablation 1: fused Mac&Load (Flex-V) vs explicit loads (MPIC), native mixed ==");
    for prec in [Precision::new(8, 4), Precision::new(4, 2), Precision::new(2, 2)] {
        let ml = matmul_table3_stats(IsaVariant::FlexV, prec).macs_per_cycle();
        let plain = matmul_table3_stats(IsaVariant::Mpic, prec).macs_per_cycle();
        println!("  {prec}: {ml:.1} vs {plain:.1} MAC/cyc -> Mac&Load gives {:.2}x (paper: 1.4x)", ml / plain);
    }
    println!("\n== Ablation 2: 4x4 (NN-RF) vs 4x2 blocking, uniform formats ==");
    for prec in [Precision::new(8, 8), Precision::new(4, 4), Precision::new(2, 2)] {
        let b44 = matmul_table3_stats(IsaVariant::FlexV, prec).macs_per_cycle();
        let b42 = matmul_table3_stats(IsaVariant::XpulpNn, prec).macs_per_cycle();
        println!("  {prec}: 4x4 {b44:.1} vs 4x2 {b42:.1} MAC/cyc -> {:.2}x", b44 / b42);
    }
    println!("\n== Ablation 3: hardware mixed-precision vs software unpack (same 4x2 core) ==");
    for prec in [Precision::new(8, 4), Precision::new(8, 2), Precision::new(4, 2)] {
        let hw = matmul_table3_stats(IsaVariant::Mpic, prec).macs_per_cycle();
        let sw = matmul_table3_stats(IsaVariant::XpulpNn, prec).macs_per_cycle();
        println!("  {prec}: HW {hw:.1} vs SW-unpack {sw:.1} MAC/cyc -> {:.1}x", hw / sw);
    }
    println!("\n== Ablation 4: conv overheads (im2col+requant) vs pure MatMul, Flex-V ==");
    for prec in flexv::qnn::Precision::grid() {
        let mm = matmul_table3_stats(IsaVariant::FlexV, prec).macs_per_cycle();
        let cv = conv_fig7_stats(IsaVariant::FlexV, prec).macs_per_cycle();
        println!("  {prec}: MatMul {mm:.1} -> conv {cv:.1} MAC/cyc ({:.0}% overhead)", (1.0 - cv / mm) * 100.0);
    }
    flexv::report::bench::write_artifact_from_args(
        "kernels",
        &flexv::report::bench::BenchOptions::default(),
    );
}
