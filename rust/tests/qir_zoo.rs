//! Zoo equivalence: the graph-IR twins of the paper networks lower to
//! bit-identical networks, deployment plans and serving fingerprints as
//! the hand-coded builders; the committed `.qir` files reproduce the
//! builders at the canonical input sizes; and every extension model runs
//! end-to-end bit-exact against the golden executor.

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::deploy;
use flexv::dory::{MemBudget, PlanKey};
use flexv::isa::IsaVariant;
use flexv::models;
use flexv::qnn::{golden, qir, QTensor};
use flexv::util::Prng;

#[test]
fn paper_twins_lower_bit_identically() {
    let budget = MemBudget::default();
    for name in models::MODEL_NAMES {
        let hand = models::by_name(name, 96).expect("paper model");
        let twin =
            models::graph_by_name(name, 96).expect("paper graph").lower().expect("twin lowers");
        assert_eq!(format!("{twin:?}"), format!("{hand:?}"), "{name}: networks differ");
        let key_h = PlanKey::for_network(&hand, IsaVariant::FlexV, budget, flexv::CLUSTER_CORES);
        let key_t = PlanKey::for_network(&twin, IsaVariant::FlexV, budget, flexv::CLUSTER_CORES);
        assert_eq!(key_h, key_t, "{name}: plan fingerprints differ");
        let dep_h = deploy(&hand, IsaVariant::FlexV, budget);
        let dep_t = deploy(&twin, IsaVariant::FlexV, budget);
        assert_eq!(format!("{dep_t:?}"), format!("{dep_h:?}"), "{name}: deployment plans differ");
    }
}

#[test]
fn committed_paper_files_match_builders_at_canonical_inputs() {
    // parse(models/<name>.qir) -> lower() == the hand-coded builder at
    // the canonical input size (224x224 MobileNet, 32x32 ResNet): the
    // text files are a complete, equivalent description of the paper
    // networks, weights included (same seeded stream).
    for name in models::MODEL_NAMES {
        let text = models::committed_qir(name).expect("paper model has a committed .qir");
        let from_file = qir::parse(text).expect("committed file parses").lower().expect("lowers");
        let hand = models::by_name(name, 224).unwrap();
        assert_eq!(
            format!("{from_file:?}"),
            format!("{hand:?}"),
            "{name}: models/{name}.qir does not reproduce the hand-coded network"
        );
    }
}

#[test]
fn serve_fingerprints_match_for_twin_networks() {
    use flexv::serve::{Engine, ServeConfig};
    let mk = |nets: Vec<flexv::qnn::Network>| {
        let mut eng = Engine::new(ServeConfig::default());
        for n in nets {
            eng.register(n);
        }
        eng
    };
    let hand =
        mk(models::MODEL_NAMES.into_iter().map(|n| models::by_name(n, 96).unwrap()).collect());
    let twins = mk(models::MODEL_NAMES
        .into_iter()
        .map(|n| models::graph_by_name(n, 96).unwrap().lower().unwrap())
        .collect());
    for m in 0..hand.model_count() {
        let (_, key_h) = hand.model_entry(m);
        let (_, key_t) = twins.model_entry(m);
        assert_eq!(key_h, key_t, "model {m}: serving fingerprint (PlanKey) differs");
    }
}

#[test]
fn extension_models_run_bit_exact_against_golden() {
    let ext: Vec<&str> = models::ZOO_NAMES
        .iter()
        .copied()
        .filter(|n| !models::MODEL_NAMES.contains(n))
        .collect();
    assert_eq!(ext.len(), 3, "three extension models beyond the paper's zoo");
    for name in ext {
        let net = models::by_name(name, 96).expect("extension model loads");
        let mut rng = Prng::new(0xD1FF ^ net.nodes.len() as u64);
        let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
        let golden_outs = golden::run_network(&net, &input);
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
        let res = coord.run(&dep, &input);
        for (i, gold) in golden_outs.iter().enumerate() {
            assert_eq!(
                res.node_outputs[i],
                gold.data,
                "{name}: node {i} ({}) mismatch",
                net.nodes[i].layer.name
            );
        }
    }
}
