//! The end-to-end network zoo of the evaluation (§V-C, Table IV) plus the
//! extension models documented in `models/README.md`.
//!
//! Paper networks: MobileNetV1 (8-bit and mixed 8b4b) and ResNet-20 (mixed
//! 4b2b). Extension networks (committed as `.qir` files under `models/`,
//! see `docs/QIR_FORMAT.md`): DS-CNN keyword spotting, a residual
//! depthwise-separable stack, and a two-branch MLP-mixer-ish block
//! exercising `Concat`.
//!
//! Weights are synthetic (seeded): performance and memory footprint depend
//! only on topology and per-layer precision, not on learned values
//! (DESIGN.md §2). Top-1 accuracies in Table IV are therefore *cited* from
//! the paper, not re-measured.
//!
//! Every paper network exists in two forms that are proven bit-identical by
//! tests (`rust/tests/qir_zoo.rs`): the hand-coded [`Network`] builder
//! ([`mobilenet_v1`], [`resnet20`]) and a graph-IR twin
//! ([`mobilenet_v1_graph`], [`resnet20_graph`]) whose [`Graph::lower`]
//! reproduces the exact same layers, weight streams, deployment plans and
//! serve fingerprints. Extension models exist only in `.qir` form.
//!
//! Precision assignments:
//! - **MNV1 8b**: a8w8 everywhere.
//! - **MNV1 8b4b** ("fully mixed-precision"): 8-bit activations, 4-bit
//!   weights on every layer except the first convolution (w8), halving the
//!   weight footprint (the paper's −47%).
//! - **ResNet-20 4b2b** (HAWQ-style [18]): 4-bit activations; 2-bit
//!   weights in stages 1-2, 4-bit in stage 3 (where the parameters
//!   concentrate), 8-bit first conv and classifier — reproducing the
//!   ~142 kB footprint of Table IV.

use crate::qnn::graph::{Graph, OpKind};
use crate::qnn::layer::{Layer, LayerKind, Network};
use crate::qnn::{qir, QTensor, QuantParams};
use crate::util::Prng;

/// Precision profile of a network build.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Profile {
    /// Uniform 8-bit.
    Uniform8,
    /// Mixed 8-bit activations / 4-bit weights.
    Mixed8a4w,
    /// Aggressive mixed 4-bit activations / 2-4-bit weights.
    Mixed4a2w,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Uniform8 => "8b",
            Profile::Mixed8a4w => "8b4b",
            Profile::Mixed4a2w => "4b2b",
        }
    }
}

/// Benign requant parameters keeping activations well-distributed for the
/// synthetic weights (shift balances the accumulation growth).
fn quant_for(k: usize, a_bits: u8, w_bits: u8, out_bits: u8, ch: usize) -> QuantParams {
    let acc_bits = (a_bits as u32 + w_bits as u32 - 1)
        + (k.max(1).next_power_of_two().trailing_zeros());
    let shift = (acc_bits as i32 - out_bits as i32 - 1).clamp(0, 31) as u8;
    QuantParams::scalar(1, shift, 0, out_bits, ch)
}

#[allow(clippy::too_many_arguments)]
fn conv(
    name: String,
    in_shape: [usize; 3],
    cout: usize,
    k: usize,
    stride: usize,
    a_bits: u8,
    w_bits: u8,
    out_bits: u8,
    rng: &mut Prng,
) -> Layer {
    let [h, w, cin] = in_shape;
    let pad = k / 2;
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    Layer {
        name,
        kind: LayerKind::Conv2d { kh: k, kw: k, stride, pad },
        in_shape,
        out_shape: [oh, ow, cout],
        a_bits,
        w_bits,
        weights: Some(QTensor::random(&[cout, k, k, cin], w_bits, true, rng)),
        quant: quant_for(k * k * cin, a_bits, w_bits, out_bits, cout),
    }
}

fn dwconv(
    name: String,
    in_shape: [usize; 3],
    stride: usize,
    a_bits: u8,
    w_bits: u8,
    rng: &mut Prng,
) -> Layer {
    let [h, w, c] = in_shape;
    let oh = (h + 2 - 3) / stride + 1;
    let ow = (w + 2 - 3) / stride + 1;
    Layer {
        name,
        kind: LayerKind::DwConv2d { kh: 3, kw: 3, stride, pad: 1 },
        in_shape,
        out_shape: [oh, ow, c],
        a_bits,
        w_bits,
        weights: Some(QTensor::random(&[c, 3, 3, 1], w_bits, true, rng)),
        quant: quant_for(9, a_bits, w_bits, a_bits, c),
    }
}

/// The 13 depthwise-separable block configs of MobileNetV1:
/// (full-width output channels, stride).
const MNV1_BLOCKS: [(usize, usize); 13] = [
    (64, 1),
    (128, 2),
    (128, 1),
    (256, 2),
    (256, 1),
    (512, 2),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (1024, 2),
    (1024, 1),
];

/// MobileNetV1 with width multiplier `alpha` (default 0.75 — the
/// CMix-NN/STM32H7 comparison point; the paper's 1.9 MB model size points
/// to a reduced-width variant, see EXPERIMENTS.md).
pub fn mobilenet_v1(profile: Profile, alpha: f64, input_hw: usize, seed: u64) -> Network {
    assert!(profile != Profile::Mixed4a2w, "MNV1 profiles are 8b / 8b4b");
    let mut rng = Prng::new(seed);
    let w4 = profile == Profile::Mixed8a4w;
    let ch = |c: usize| (((c as f64 * alpha) / 8.0).round() as usize * 8).max(8);
    let mut net = Network::new(
        &format!("MobileNetV1-{}(a{alpha})", profile.name()),
        [input_hw, input_hw, 4],
        8,
    );
    // Stem: the 3-channel RGB input is zero-padded to 4 channels at
    // deployment (DORY byte-alignment; the pad channel is zero so the
    // extra MACs are value-neutral but counted as in the paper's k=27+).
    let mut shape = [input_hw, input_hw, 4];
    let stem = conv("conv1".into(), shape, ch(32), 3, 2, 8, 8, 8, &mut rng);
    shape = stem.out_shape;
    net.push(stem);
    // 13 depthwise-separable blocks.
    for (i, &(cout, stride)) in MNV1_BLOCKS.iter().enumerate() {
        let dw = dwconv(
            format!("dw{}", i + 1),
            shape,
            stride,
            8,
            if w4 { 4 } else { 8 },
            &mut rng,
        );
        shape = dw.out_shape;
        net.push(dw);
        let pw = conv(
            format!("pw{}", i + 1),
            shape,
            ch(cout),
            1,
            1,
            8,
            if w4 { 4 } else { 8 },
            8,
            &mut rng,
        );
        shape = pw.out_shape;
        net.push(pw);
    }
    // Global average pool + classifier.
    let [h, _, c] = shape;
    net.push(Layer {
        name: "avgpool".into(),
        kind: LayerKind::AvgPool { k: h, stride: h },
        in_shape: shape,
        out_shape: [1, 1, c],
        a_bits: 8,
        w_bits: 8,
        weights: None,
        // divide by h*h: mult/shift approximating 1/49 etc.
        quant: QuantParams::scalar(
            ((1i64 << 16) / (h * h) as i64) as i32,
            16,
            0,
            8,
            c,
        ),
    });
    let classes = 1000usize;
    let mut rng2 = Prng::new(seed ^ 0xFC);
    net.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Linear,
        in_shape: [1, 1, c],
        out_shape: [1, 1, classes],
        a_bits: 8,
        w_bits: if w4 { 4 } else { 8 },
        weights: Some(QTensor::random(&[classes, c], if w4 { 4 } else { 8 }, true, &mut rng2)),
        quant: quant_for(c, 8, if w4 { 4 } else { 8 }, 8, classes),
    });
    net
}

/// The graph-IR twin of [`mobilenet_v1`]: same ops in the same definition
/// order with the same quantizers, so [`Graph::lower`] reproduces the
/// hand-coded network bit-for-bit (weights included — the classifier
/// carries the same `seed ^ 0xFC` stream override as the builder's
/// dedicated PRNG).
pub fn mobilenet_v1_graph(profile: Profile, alpha: f64, input_hw: usize, seed: u64) -> Graph {
    assert!(profile != Profile::Mixed4a2w, "MNV1 profiles are 8b / 8b4b");
    let wb = if profile == Profile::Mixed8a4w { 4 } else { 8 };
    let ch = |c: usize| (((c as f64 * alpha) / 8.0).round() as usize * 8).max(8);
    let mut g = Graph::new(
        &format!("MobileNetV1-{}(a{alpha})", profile.name()),
        [input_hw, input_hw, 4],
        8,
        seed,
    );
    let mut shape = [input_hw, input_hw, 4];
    let mut t = g.input;
    let out = [(input_hw - 1) / 2 + 1, (input_hw - 1) / 2 + 1, ch(32)];
    t = g.op(
        "conv1",
        OpKind::Conv2d { kh: 3, kw: 3, stride: 2, pad: 1 },
        &[t],
        8,
        out,
        quant_for(3 * 3 * shape[2], 8, 8, 8, ch(32)),
        None,
    );
    shape = out;
    for (i, &(cout, stride)) in MNV1_BLOCKS.iter().enumerate() {
        let od = [(shape[0] - 1) / stride + 1, (shape[1] - 1) / stride + 1, shape[2]];
        t = g.op(
            &format!("dw{}", i + 1),
            OpKind::DwConv2d { kh: 3, kw: 3, stride, pad: 1 },
            &[t],
            wb,
            od,
            quant_for(9, 8, wb, 8, shape[2]),
            None,
        );
        shape = od;
        let op = [shape[0], shape[1], ch(cout)];
        t = g.op(
            &format!("pw{}", i + 1),
            OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
            &[t],
            wb,
            op,
            quant_for(shape[2], 8, wb, 8, ch(cout)),
            None,
        );
        shape = op;
    }
    let [h, _, c] = shape;
    t = g.op(
        "avgpool",
        OpKind::AvgPool { k: h, stride: h },
        &[t],
        8,
        [1, 1, c],
        QuantParams::scalar(((1i64 << 16) / (h * h) as i64) as i32, 16, 0, 8, c),
        None,
    );
    g.op(
        "fc",
        OpKind::Linear,
        &[t],
        wb,
        [1, 1, 1000],
        quant_for(c, 8, wb, 8, 1000),
        Some(seed ^ 0xFC),
    );
    g
}

/// ResNet-20 for CIFAR-10 (32×32 input), HAWQ-style mixed 4b2b profile
/// (or uniform 8b for the degradation baseline).
pub fn resnet20(profile: Profile, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let (a_bits, w_early, w_late): (u8, u8, u8) = match profile {
        Profile::Uniform8 => (8, 8, 8),
        Profile::Mixed4a2w => (4, 2, 4),
        Profile::Mixed8a4w => (8, 4, 4),
    };
    let mut net = Network::new(
        &format!("ResNet20-{}", profile.name()),
        [32, 32, 4],
        8,
    );
    // Stem (RGB padded to 4 channels, 8-bit I/O then quantized down).
    let stem = conv("conv1".into(), [32, 32, 4], 16, 3, 1, 8, 8, a_bits, &mut rng);
    let mut shape = stem.out_shape;
    let mut prev = net.push(stem);
    // 3 stages × 3 basic blocks.
    let stage_ch = [16usize, 32, 64];
    for (s, &c) in stage_ch.iter().enumerate() {
        for b in 0..3 {
            // HAWQ-style assignment: the two widest blocks (stage 3,
            // blocks 1-2) carry most parameters and the most Hessian
            // sensitivity -> 4-bit; everything else 2-bit.
            let wb = if s == 2 && b > 0 { w_late } else { w_early };
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let c1 = conv(
                format!("s{s}b{b}c1"),
                shape,
                c,
                3,
                stride,
                a_bits,
                wb,
                a_bits,
                &mut rng,
            );
            let c1_shape = c1.out_shape;
            let id1 = net.push_with_inputs(c1, vec![prev]);
            let c2 = conv(format!("s{s}b{b}c2"), c1_shape, c, 3, 1, a_bits, wb, a_bits, &mut rng);
            let c2_shape = c2.out_shape;
            let id2 = net.push_with_inputs(c2, vec![id1]);
            // Shortcut: identity, or 1×1/s2 projection on stage entry.
            let short = if stride != 1 || shape[2] != c {
                let proj = conv(
                    format!("s{s}b{b}proj"),
                    shape,
                    c,
                    1,
                    stride,
                    a_bits,
                    wb,
                    a_bits,
                    &mut rng,
                );
                net.push_with_inputs(proj, vec![prev])
            } else {
                prev
            };
            let add = Layer {
                name: format!("s{s}b{b}add"),
                kind: LayerKind::Add { m1: 1, m2: 1 },
                in_shape: c2_shape,
                out_shape: c2_shape,
                a_bits,
                w_bits: 8,
                weights: None,
                quant: QuantParams::scalar(1, 1, 0, a_bits, c),
            };
            prev = net.push_with_inputs(add, vec![id2, short]);
            shape = c2_shape;
        }
    }
    // Global average pool + 10-class (padded to 12) classifier.
    let [h, _, c] = shape;
    net.push_with_inputs(
        Layer {
            name: "avgpool".into(),
            kind: LayerKind::AvgPool { k: h, stride: h },
            in_shape: shape,
            out_shape: [1, 1, c],
            a_bits,
            w_bits: 8,
            weights: None,
            quant: QuantParams::scalar(
                ((1i64 << 16) / (h * h) as i64) as i32,
                16,
                0,
                8,
                c,
            ),
        },
        vec![prev],
    );
    net.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Linear,
        in_shape: [1, 1, c],
        out_shape: [1, 1, 12], // 10 classes padded to a multiple of 4
        a_bits: 8,
        w_bits: 8,
        weights: Some(QTensor::random(&[12, c], 8, true, &mut rng)),
        quant: quant_for(c, 8, 8, 8, 12),
    });
    net
}

/// The graph-IR twin of [`resnet20`]: identical op definition order
/// (c1, c2, optional projection, add per block) so the shared weight
/// stream draws in the same sequence as the hand-coded builder.
pub fn resnet20_graph(profile: Profile, seed: u64) -> Graph {
    let (a_bits, w_early, w_late): (u8, u8, u8) = match profile {
        Profile::Uniform8 => (8, 8, 8),
        Profile::Mixed4a2w => (4, 2, 4),
        Profile::Mixed8a4w => (8, 4, 4),
    };
    let mut g = Graph::new(&format!("ResNet20-{}", profile.name()), [32, 32, 4], 8, seed);
    let mut t = g.op(
        "conv1",
        OpKind::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
        &[g.input],
        8,
        [32, 32, 16],
        quant_for(3 * 3 * 4, 8, 8, a_bits, 16),
        None,
    );
    let mut shape = [32, 32, 16];
    let stage_ch = [16usize, 32, 64];
    for (s, &c) in stage_ch.iter().enumerate() {
        for b in 0..3 {
            let wb = if s == 2 && b > 0 { w_late } else { w_early };
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let o = [(shape[0] - 1) / stride + 1, (shape[1] - 1) / stride + 1, c];
            let id1 = g.op(
                &format!("s{s}b{b}c1"),
                OpKind::Conv2d { kh: 3, kw: 3, stride, pad: 1 },
                &[t],
                wb,
                o,
                quant_for(3 * 3 * shape[2], a_bits, wb, a_bits, c),
                None,
            );
            let id2 = g.op(
                &format!("s{s}b{b}c2"),
                OpKind::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
                &[id1],
                wb,
                o,
                quant_for(3 * 3 * c, a_bits, wb, a_bits, c),
                None,
            );
            let short = if stride != 1 || shape[2] != c {
                g.op(
                    &format!("s{s}b{b}proj"),
                    OpKind::Conv2d { kh: 1, kw: 1, stride, pad: 0 },
                    &[t],
                    wb,
                    o,
                    quant_for(shape[2], a_bits, wb, a_bits, c),
                    None,
                )
            } else {
                t
            };
            t = g.op(
                &format!("s{s}b{b}add"),
                OpKind::Add { m1: 1, m2: 1 },
                &[id2, short],
                8,
                o,
                QuantParams::scalar(1, 1, 0, a_bits, c),
                None,
            );
            shape = o;
        }
    }
    let [h, _, c] = shape;
    t = g.op(
        "avgpool",
        OpKind::AvgPool { k: h, stride: h },
        &[t],
        8,
        [1, 1, c],
        QuantParams::scalar(((1i64 << 16) / (h * h) as i64) as i32, 16, 0, 8, c),
        None,
    );
    g.op("fc", OpKind::Linear, &[t], 8, [1, 1, 12], quant_for(c, 8, 8, 8, 12), None);
    g
}

/// Why [`by_name`] could not produce a network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelError {
    /// Neither a registry name nor a readable `.qir` file.
    UnknownName { name: String },
    /// A `.qir` file exists but could not be read.
    Io { path: String, err: String },
    /// A `.qir` source was read but failed to parse or lower.
    Invalid { path: String, err: String },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::UnknownName { name } => write!(
                f,
                "unknown model '{name}': known models are {}; a `.qir` file name is \
                 searched at {}",
                ZOO_NAMES.join(", "),
                qir_search_paths(name).join(", "),
            ),
            ModelError::Io { path, err } => write!(f, "cannot read model '{path}': {err}"),
            ModelError::Invalid { path, err } => write!(f, "invalid model '{path}': {err}"),
        }
    }
}

impl std::error::Error for ModelError {}

/// The three paper workloads (Table IV order — the serve standard mix and
/// the report generators index this).
pub const MODEL_NAMES: [&str; 3] = ["mnv1-8b", "mnv1-8b4b", "resnet20-4b2b"];

/// The full zoo: paper workloads first (== [`MODEL_NAMES`]), then the
/// extension models committed as `models/*.qir`.
pub const ZOO_NAMES: [&str; 6] = [
    "mnv1-8b",
    "mnv1-8b4b",
    "resnet20-4b2b",
    "dscnn-8b4b",
    "resdw-8b4b",
    "mixer-8b4b",
];

/// The committed `.qir` source of a zoo model (embedded at build time from
/// `models/`; paper networks at their canonical 224×224 / 32×32 inputs).
pub fn committed_qir(name: &str) -> Option<&'static str> {
    match name {
        "mnv1-8b" => Some(include_str!("../../../models/mnv1-8b.qir")),
        "mnv1-8b4b" => Some(include_str!("../../../models/mnv1-8b4b.qir")),
        "resnet20-4b2b" => Some(include_str!("../../../models/resnet20-4b2b.qir")),
        "dscnn-8b4b" => Some(include_str!("../../../models/dscnn-8b4b.qir")),
        "resdw-8b4b" => Some(include_str!("../../../models/resdw-8b4b.qir")),
        "mixer-8b4b" => Some(include_str!("../../../models/mixer-8b4b.qir")),
        _ => None,
    }
}

/// Paths [`by_name`] tries, in order, for a name routed to the filesystem
/// (one ending in `.qir` or containing `/`).
pub fn qir_search_paths(name: &str) -> Vec<String> {
    let mut out = vec![name.to_string()];
    if !name.contains('/') {
        out.push(format!("models/{name}"));
        if !name.ends_with(".qir") {
            out.push(format!("models/{name}.qir"));
        }
    }
    out
}

fn parse_and_lower(text: &str, origin: &str) -> Result<Network, ModelError> {
    let g = qir::parse(text)
        .map_err(|e| ModelError::Invalid { path: origin.into(), err: e.to_string() })?;
    g.lower().map_err(|e| ModelError::Invalid { path: origin.into(), err: e })
}

fn load_qir_file(name: &str) -> Result<Network, ModelError> {
    for path in qir_search_paths(name) {
        match std::fs::read_to_string(&path) {
            Ok(text) => return parse_and_lower(&text, &path),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
            Err(e) => return Err(ModelError::Io { path, err: e.to_string() }),
        }
    }
    Err(ModelError::UnknownName { name: name.into() })
}

/// Look up an evaluation network by its CLI name ([`ZOO_NAMES`]) or by a
/// `.qir` file path. `input_hw` sets the MobileNet input resolution
/// (every other model has a fixed input). Seeds match the `run-net`
/// subcommand and the Table IV generators, so every consumer (CLI,
/// report, serve engine) builds bit-identical networks — which is what
/// lets the serve plan cache key them structurally.
///
/// Names ending in `.qir` (or containing `/`) are read from the
/// filesystem via [`qir_search_paths`]; registry extension models come
/// from the embedded committed sources ([`committed_qir`]).
pub fn by_name(name: &str, input_hw: usize) -> Result<Network, ModelError> {
    match name {
        "mnv1-8b" => Ok(mobilenet_v1(Profile::Uniform8, 0.75, input_hw, 11)),
        "mnv1-8b4b" => Ok(mobilenet_v1(Profile::Mixed8a4w, 0.75, input_hw, 11)),
        "resnet20-4b2b" => Ok(resnet20(Profile::Mixed4a2w, 12)),
        "dscnn-8b4b" | "resdw-8b4b" | "mixer-8b4b" => {
            parse_and_lower(committed_qir(name).expect("registry name"), name)
        }
        _ if name.ends_with(".qir") || name.contains('/') => load_qir_file(name),
        _ => Err(ModelError::UnknownName { name: name.into() }),
    }
}

/// The graph-IR form of a registry model: paper networks from their graph
/// builders (parameterized by `input_hw`), extension networks parsed from
/// the embedded committed `.qir` source. The `qir export` CLI prints this
/// graph canonically; CI byte-diffs the export against `models/*.qir`.
pub fn graph_by_name(name: &str, input_hw: usize) -> Result<Graph, ModelError> {
    match name {
        "mnv1-8b" => Ok(mobilenet_v1_graph(Profile::Uniform8, 0.75, input_hw, 11)),
        "mnv1-8b4b" => Ok(mobilenet_v1_graph(Profile::Mixed8a4w, 0.75, input_hw, 11)),
        "resnet20-4b2b" => Ok(resnet20_graph(Profile::Mixed4a2w, 12)),
        _ => {
            let text = committed_qir(name)
                .ok_or_else(|| ModelError::UnknownName { name: name.into() })?;
            qir::parse(text)
                .map_err(|e| ModelError::Invalid { path: name.into(), err: e.to_string() })
        }
    }
}

/// Table IV's cited accuracies (not re-measured; weights are synthetic).
/// Extension models have no paper anchor and return `None`.
pub fn cited_accuracy(net_name: &str) -> Option<f64> {
    if net_name.starts_with("MobileNetV1-8b4b") {
        Some(66.0)
    } else if net_name.starts_with("MobileNetV1-8b") {
        Some(69.3)
    } else if net_name.starts_with("ResNet20-4b2b") {
        Some(90.2)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::layer::NET_INPUT;

    #[test]
    fn mnv1_8b_validates_and_counts() {
        let net = mobilenet_v1(Profile::Uniform8, 0.75, 224, 1);
        net.validate().expect("MNV1 invalid");
        // 27 conv/dw layers + pool + fc = 29 nodes
        assert_eq!(net.nodes.len(), 29);
        // MACs in the hundreds of millions at 224x224
        let m = net.total_macs();
        assert!(m > 200e6 as u64 && m < 800e6 as u64, "MACs {m}");
    }

    #[test]
    fn mnv1_mixed_halves_weight_footprint() {
        let full = mobilenet_v1(Profile::Uniform8, 0.75, 224, 1);
        let mixed = mobilenet_v1(Profile::Mixed8a4w, 0.75, 224, 1);
        let (a, b) = (full.model_bytes() as f64, mixed.model_bytes() as f64);
        let saved = 1.0 - b / a;
        // paper: 47% saved
        assert!(saved > 0.40 && saved < 0.55, "saved {saved}");
    }

    #[test]
    fn resnet20_4b2b_footprint_near_table4() {
        let net = resnet20(Profile::Mixed4a2w, 2);
        net.validate().expect("ResNet20 invalid");
        let kb = net.model_bytes() as f64 / 1024.0;
        // Table IV: 142 kB
        assert!(kb > 100.0 && kb < 180.0, "footprint {kb} kB");
        let full = resnet20(Profile::Uniform8, 2);
        let saved = 1.0 - net.model_bytes() as f64 / full.model_bytes() as f64;
        // paper: 63% saved
        assert!(saved > 0.55 && saved < 0.72, "saved {saved}");
    }

    #[test]
    fn resnet20_has_residual_adds() {
        let net = resnet20(Profile::Mixed4a2w, 2);
        let adds = net
            .nodes
            .iter()
            .filter(|n| matches!(n.layer.kind, LayerKind::Add { .. }))
            .count();
        assert_eq!(adds, 9);
        // at least one node consumes the network input
        assert!(net.nodes.iter().any(|n| n.inputs.contains(&NET_INPUT)));
    }

    #[test]
    fn by_name_covers_the_zoo_deterministically() {
        for name in ZOO_NAMES {
            let a = by_name(name, 96).expect(name);
            let b = by_name(name, 96).expect(name);
            a.validate().expect(name);
            assert_eq!(a.name, b.name);
            assert_eq!(a.model_bytes(), b.model_bytes());
        }
    }

    #[test]
    fn by_name_reports_unknown_names_helpfully() {
        let e = by_name("nope", 96).unwrap_err();
        assert!(matches!(e, ModelError::UnknownName { .. }), "{e:?}");
        let msg = e.to_string();
        for name in ZOO_NAMES {
            assert!(msg.contains(name), "error must list {name}: {msg}");
        }
        // a `.qir`-suffixed name that resolves nowhere names its search paths
        let e = by_name("missing.qir", 96).unwrap_err().to_string();
        assert!(e.contains("models/missing.qir"), "{e}");
    }

    #[test]
    fn graph_twins_lower_to_the_hand_coded_networks() {
        // Debug equality covers every field including the weight bytes.
        for (g, n) in [
            (
                mobilenet_v1_graph(Profile::Uniform8, 0.75, 96, 11),
                mobilenet_v1(Profile::Uniform8, 0.75, 96, 11),
            ),
            (
                mobilenet_v1_graph(Profile::Mixed8a4w, 0.75, 96, 11),
                mobilenet_v1(Profile::Mixed8a4w, 0.75, 96, 11),
            ),
            (resnet20_graph(Profile::Mixed4a2w, 12), resnet20(Profile::Mixed4a2w, 12)),
            (resnet20_graph(Profile::Uniform8, 12), resnet20(Profile::Uniform8, 12)),
        ] {
            let lowered = g.lower().expect(&n.name);
            assert_eq!(format!("{lowered:?}"), format!("{n:?}"), "{} twin differs", n.name);
        }
    }

    #[test]
    fn extension_models_load_and_validate() {
        let dscnn = by_name("dscnn-8b4b", 96).expect("dscnn");
        assert_eq!(dscnn.input_shape, [48, 12, 4]);
        assert_eq!(dscnn.nodes.len(), 11);
        let resdw = by_name("resdw-8b4b", 96).expect("resdw");
        assert_eq!(resdw.nodes.len(), 17);
        assert!(resdw
            .nodes
            .iter()
            .any(|n| matches!(n.layer.kind, LayerKind::MaxPool { .. })));
        let mixer = by_name("mixer-8b4b", 96).expect("mixer");
        assert_eq!(mixer.nodes.len(), 10);
        assert!(mixer
            .nodes
            .iter()
            .any(|n| matches!(n.layer.kind, LayerKind::Concat)));
    }

    #[test]
    fn channel_counts_stay_byte_aligned() {
        for name in ZOO_NAMES {
            let net = by_name(name, 96).expect(name);
            for node in &net.nodes {
                let l = &node.layer;
                assert_eq!(
                    l.out_shape[2] * l.quant.out_bits as usize % 8,
                    0,
                    "{}/{} misaligned",
                    net.name,
                    l.name
                );
            }
        }
    }
}
