//! Assembly parser: the inverse of [`crate::isa::disasm`].
//!
//! [`parse`] turns one line of the disassembler's Fig.-5-style notation
//! back into the instruction IR, so `encode → disasm → parse` is a
//! roundtrip over every kernel the generators emit (property-tested
//! below across all [`crate::isa::IsaVariant`]s × the paper's
//! precision grid).
//!
//! # Representation conventions (the documented asymmetries)
//!
//! Three pieces of IR state have no slot in the textual encoding; the
//! disassembler renders them as a trailing `#` comment, which this
//! parser treats as **load-bearing**:
//!
//! - `mpc_cnt=N` — the MPC subgroup counter of a (mixed-precision)
//!   `pv.sdotusp`/`pv.mlsdotusp` (hardware derives it from CSR state,
//!   the IR carries it inline). Omitted ⇒ `sub == 0`.
//! - `wb-load <slot> <- <ch>` — the fused write-back load of a
//!   Mac&Load (hardware derives target slot and channel from the MLC;
//!   the IR carries them inline). Omitted ⇒ [`MlUpdate::None`].
//! - Post-modified memory ops (`p.lw x1, 4(x2!)`) render only the
//!   post-increment: the XpulpV2 encoding has no separate offset field
//!   for them, so an IR value with both `off != 0` and `post_inc != 0`
//!   would be lossy. The kernel generators never emit that combination
//!   (asserted by the roundtrip test), and [`parse`] always returns
//!   `off == 0` for the post-modified form.

use super::instr::{AluOp, Cond, Csr, Instr, MlChannel, MlUpdate, NnSlot, Reg, SimdFmt};

fn fmt_from_suffix(c: char) -> Option<SimdFmt> {
    Some(match c {
        'h' => SimdFmt::Half,
        'b' => SimdFmt::Byte,
        'n' => SimdFmt::Nibble,
        'c' => SimdFmt::Crumb,
        _ => return None,
    })
}

/// Inverse of [`crate::isa::disasm`]'s `mix_suffix`: one letter = both
/// operands share the format, two letters = activation then weight.
fn fmts_from_mix(mix: &str) -> Option<(SimdFmt, SimdFmt)> {
    let fmts: Vec<SimdFmt> = mix.chars().map(fmt_from_suffix).collect::<Option<_>>()?;
    match fmts.as_slice() {
        [f] => Some((*f, *f)),
        [a, w] => Some((*a, *w)),
        _ => None,
    }
}

fn csr_from_name(s: &str) -> Option<Csr> {
    Some(match s {
        "simd_fmt" => Csr::SimdFmt,
        "mix_skip" => Csr::MixSkip,
        "sb_legacy" => Csr::SbLegacy,
        "a_stride" => Csr::AStride,
        "w_stride" => Csr::WStride,
        "a_rollback" => Csr::ARollback,
        "w_rollback" => Csr::WRollback,
        "a_skip" => Csr::ASkip,
        "w_skip" => Csr::WSkip,
        "a_csr" => Csr::ABase,
        "w_csr" => Csr::WBase,
        _ => return None,
    })
}

fn alu_from_name(s: &str) -> Option<AluOp> {
    Some(match s {
        "add" => AluOp::Add,
        "sub" => AluOp::Sub,
        "and" => AluOp::And,
        "or" => AluOp::Or,
        "xor" => AluOp::Xor,
        "sll" => AluOp::Sll,
        "srl" => AluOp::Srl,
        "sra" => AluOp::Sra,
        "mul" => AluOp::Mul,
        "min" => AluOp::Min,
        "max" => AluOp::Max,
        _ => return None,
    })
}

/// `x{n}` → register index.
fn reg(tok: &str) -> Option<Reg> {
    tok.strip_prefix('x')?.parse().ok()
}

/// `w{n}` / `a{n}` → NN-RF slot index (weights 0-3, activations 4-5).
fn nn_slot(tok: &str) -> Option<NnSlot> {
    if let Some(n) = tok.strip_prefix('w') {
        let n: u8 = n.parse().ok()?;
        (n < 4).then_some(n)
    } else if let Some(n) = tok.strip_prefix('a') {
        let n: u8 = n.parse().ok()?;
        (n < 2).then_some(4 + n)
    } else {
        None
    }
}

/// Signed decimal (with optional sign) or `0x…` two's-complement hex.
fn imm_i32(tok: &str) -> Option<i32> {
    if let Some(hex) = tok.strip_prefix("0x") {
        u32::from_str_radix(hex, 16).ok().map(|v| v as i32)
    } else {
        tok.parse().ok()
    }
}

fn imm_u32(tok: &str) -> Option<u32> {
    if let Some(hex) = tok.strip_prefix("0x") {
        u32::from_str_radix(hex, 16).ok()
    } else {
        tok.parse().ok()
    }
}

/// `{v}(x{base})` → (base, v, false) | `{v}(x{base}!)` → (base, v, true).
fn mem_operand(tok: &str) -> Option<(Reg, i32, bool)> {
    let open = tok.find('(')?;
    let v: i32 = tok[..open].parse().ok()?;
    let inner = tok[open + 1..].strip_suffix(')')?;
    let (inner, post) = match inner.strip_suffix('!') {
        Some(i) => (i, true),
        None => (inner, false),
    };
    Some((reg(inner)?, v, post))
}

fn ch_from_name(s: &str) -> Option<MlChannel> {
    match s {
        "a_ch" => Some(MlChannel::Act),
        "w_ch" => Some(MlChannel::Wgt),
        _ => None,
    }
}

/// Parse one line of disassembly (optionally carrying the disassembler's
/// `#` comment) back into an [`Instr`]. Returns `None` for anything the
/// disassembler cannot have produced.
pub fn parse(line: &str) -> Option<Instr> {
    let s = line.trim();
    let (code, comment) = match s.find('#') {
        Some(i) => (s[..i].trim_end(), s[i + 1..].trim()),
        None => (s, ""),
    };
    let mut words = code.split_whitespace();
    let mnem = words.next()?;
    let rest: String = words.collect::<Vec<_>>().join(" ");
    let ops: Vec<&str> =
        rest.split(',').map(|o| o.trim()).filter(|o| !o.is_empty()).collect();
    // comment notes: "mpc_cnt=N" and/or "wb-load w2 <- w_ch"
    let mut sub: u8 = 0;
    let mut upd = MlUpdate::None;
    for note in comment.split(',').map(|n| n.trim()).filter(|n| !n.is_empty()) {
        if let Some(v) = note.strip_prefix("mpc_cnt=") {
            sub = v.parse().ok()?;
        } else if let Some(rest) = note.strip_prefix("wb-load ") {
            let mut it = rest.split("<-").map(|p| p.trim());
            let slot = nn_slot(it.next()?)?;
            let ch = ch_from_name(it.next()?)?;
            upd = MlUpdate::Load { ch, slot };
        }
    }

    match mnem {
        "li" => Some(Instr::Li { rd: reg(ops.first()?)?, imm: imm_i32(ops.get(1)?)? }),
        "p.extractu" => Some(Instr::ExtractU {
            rd: reg(ops.first()?)?,
            rs1: reg(ops.get(1)?)?,
            len: ops.get(2)?.parse().ok()?,
            off: ops.get(3)?.parse().ok()?,
        }),
        "p.extract" => Some(Instr::Extract {
            rd: reg(ops.first()?)?,
            rs1: reg(ops.get(1)?)?,
            len: ops.get(2)?.parse().ok()?,
            off: ops.get(3)?.parse().ok()?,
        }),
        "p.insert" => Some(Instr::Insert {
            rd: reg(ops.first()?)?,
            rs1: reg(ops.get(1)?)?,
            len: ops.get(2)?.parse().ok()?,
            off: ops.get(3)?.parse().ok()?,
        }),
        "lw" | "p.lw" | "lbu" | "p.lbu" => {
            let rd = reg(ops.first()?)?;
            let (base, v, post) = mem_operand(ops.get(1)?)?;
            if post != (mnem.starts_with("p.")) {
                return None;
            }
            let (off, post_inc) = if post { (0, v) } else { (v, 0) };
            Some(if mnem.ends_with("lw") {
                Instr::Lw { rd, base, off, post_inc }
            } else {
                Instr::Lbu { rd, base, off, post_inc }
            })
        }
        "sw" | "p.sw" | "sb" | "p.sb" => {
            let rs = reg(ops.first()?)?;
            let (base, v, post) = mem_operand(ops.get(1)?)?;
            if post != (mnem.starts_with("p.")) {
                return None;
            }
            let (off, post_inc) = if post { (0, v) } else { (v, 0) };
            Some(if mnem.ends_with("sw") {
                Instr::Sw { rs, base, off, post_inc }
            } else {
                Instr::Sb { rs, base, off, post_inc }
            })
        }
        "p.mac" => Some(Instr::Mac {
            rd: reg(ops.first()?)?,
            rs1: reg(ops.get(1)?)?,
            rs2: reg(ops.get(2)?)?,
        }),
        "p.clipu" => Some(Instr::Clipu {
            rd: reg(ops.first()?)?,
            rs1: reg(ops.get(1)?)?,
            bits: ops.get(2)?.parse().ok()?,
        }),
        "p.nnload" => Some(Instr::NnLoad {
            slot: nn_slot(ops.first()?)?,
            ch: ch_from_name(ops.get(1)?)?,
        }),
        "csrwi" => Some(Instr::CsrW {
            csr: csr_from_name(ops.first()?)?,
            imm: imm_u32(ops.get(1)?)?,
        }),
        "lp.setup" => Some(Instr::LpSetup {
            l: ops.first()?.strip_prefix('l')?.parse().ok()?,
            count: ops.get(1)?.parse().ok()?,
            len: ops.get(2)?.strip_prefix('+')?.parse().ok()?,
        }),
        "beq" | "bne" | "blt" | "bge" => Some(Instr::Branch {
            cond: match mnem {
                "beq" => Cond::Eq,
                "bne" => Cond::Ne,
                "blt" => Cond::Lt,
                _ => Cond::Ge,
            },
            rs1: reg(ops.first()?)?,
            rs2: reg(ops.get(1)?)?,
            off: ops.get(2)?.parse().ok()?,
        }),
        "p.barrier" => ops.is_empty().then_some(Instr::Barrier),
        "halt" => ops.is_empty().then_some(Instr::Halt),
        _ => {
            if let Some(mix) = mnem.strip_prefix("pv.sdotusp.") {
                let (a_fmt, w_fmt) = fmts_from_mix(mix)?;
                return Some(Instr::Sdotp {
                    rd: reg(ops.first()?)?,
                    ra: reg(ops.get(1)?)?,
                    rw: reg(ops.get(2)?)?,
                    a_fmt,
                    w_fmt,
                    sub,
                });
            }
            if let Some(mix) = mnem.strip_prefix("pv.mlsdotusp.") {
                let (a_fmt, w_fmt) = fmts_from_mix(mix)?;
                return Some(Instr::MlSdotp {
                    acc: reg(ops.first()?)?,
                    a_slot: nn_slot(ops.get(1)?)?,
                    w_slot: nn_slot(ops.get(2)?)?,
                    a_fmt,
                    w_fmt,
                    sub,
                    upd,
                });
            }
            // ALU: register-register, or register-immediate with an
            // 'i'-suffixed mnemonic.
            if let Some(op) = alu_from_name(mnem) {
                return Some(Instr::Alu {
                    op,
                    rd: reg(ops.first()?)?,
                    rs1: reg(ops.get(1)?)?,
                    rs2: reg(ops.get(2)?)?,
                });
            }
            if let Some(op) = mnem.strip_suffix('i').and_then(alu_from_name) {
                return Some(Instr::AluI {
                    op,
                    rd: reg(ops.first()?)?,
                    rs1: reg(ops.get(1)?)?,
                    imm: imm_i32(ops.get(2)?)?,
                });
            }
            None
        }
    }
}

/// Parse a full [`disasm_program`](crate::isa::disasm::disasm_program)
/// listing: skips the header comment line and per-line `pc:` prefixes.
pub fn parse_program(listing: &str) -> Option<Vec<Instr>> {
    listing
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| {
            let body = match l.find(':') {
                Some(i) if l[..i].trim().chars().all(|c| c.is_ascii_digit()) => &l[i + 1..],
                _ => l,
            };
            parse(body)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::disasm::{disasm, disasm_program};
    use crate::isa::variant::IsaVariant;
    use crate::qnn::Precision;
    use crate::util::{proptest, Prng};

    fn roundtrip(i: Instr) {
        let text = disasm(&i);
        let back = parse(&text);
        assert_eq!(back, Some(i), "roundtrip failed for `{text}`");
    }

    /// Hand-built coverage of every IR variant, including the edge
    /// representations (negative immediates as two's-complement hex,
    /// post-modified vs offset addressing, comment-carried state).
    #[test]
    fn every_variant_roundtrips() {
        use Instr::*;
        let cases = vec![
            Li { rd: 1, imm: 0 },
            Li { rd: 31, imm: -4 },
            Li { rd: 2, imm: 0x1000_0040u32 as i32 },
            Alu { op: AluOp::Add, rd: 1, rs1: 2, rs2: 3 },
            Alu { op: AluOp::Max, rd: 30, rs1: 0, rs2: 31 },
            AluI { op: AluOp::Sra, rd: 4, rs1: 5, imm: -7 },
            AluI { op: AluOp::Add, rd: 4, rs1: 5, imm: 12 },
            ExtractU { rd: 1, rs1: 2, off: 3, len: 4 },
            Extract { rd: 1, rs1: 2, off: 0, len: 8 },
            Insert { rd: 9, rs1: 8, off: 24, len: 8 },
            Lw { rd: 1, base: 2, off: 16, post_inc: 0 },
            Lw { rd: 1, base: 2, off: 0, post_inc: 4 },
            Lbu { rd: 1, base: 2, off: -3, post_inc: 0 },
            Lbu { rd: 1, base: 2, off: 0, post_inc: 1 },
            Sw { rs: 7, base: 6, off: 0, post_inc: 0 },
            Sw { rs: 7, base: 6, off: 0, post_inc: -8 },
            Sb { rs: 7, base: 6, off: 5, post_inc: 0 },
            Sb { rs: 7, base: 6, off: 0, post_inc: 1 },
            Mac { rd: 10, rs1: 11, rs2: 12 },
            Clipu { rd: 1, rs1: 1, bits: 4 },
            Sdotp { rd: 1, ra: 2, rw: 3, a_fmt: SimdFmt::Byte, w_fmt: SimdFmt::Byte, sub: 0 },
            Sdotp { rd: 1, ra: 2, rw: 3, a_fmt: SimdFmt::Byte, w_fmt: SimdFmt::Nibble, sub: 1 },
            Sdotp { rd: 1, ra: 2, rw: 3, a_fmt: SimdFmt::Crumb, w_fmt: SimdFmt::Crumb, sub: 3 },
            Sdotp { rd: 1, ra: 2, rw: 3, a_fmt: SimdFmt::Half, w_fmt: SimdFmt::Crumb, sub: 0 },
            MlSdotp {
                acc: 1,
                a_slot: 4,
                w_slot: 0,
                a_fmt: SimdFmt::Byte,
                w_fmt: SimdFmt::Byte,
                sub: 0,
                upd: MlUpdate::None,
            },
            MlSdotp {
                acc: 1,
                a_slot: 5,
                w_slot: 3,
                a_fmt: SimdFmt::Byte,
                w_fmt: SimdFmt::Nibble,
                sub: 1,
                upd: MlUpdate::Load { ch: MlChannel::Wgt, slot: 2 },
            },
            MlSdotp {
                acc: 28,
                a_slot: 4,
                w_slot: 1,
                a_fmt: SimdFmt::Nibble,
                w_fmt: SimdFmt::Nibble,
                sub: 1,
                upd: MlUpdate::Load { ch: MlChannel::Act, slot: 5 },
            },
            NnLoad { ch: MlChannel::Act, slot: 4 },
            NnLoad { ch: MlChannel::Wgt, slot: 0 },
            CsrW { csr: Csr::SimdFmt, imm: 0x12 },
            CsrW { csr: Csr::WBase, imm: 0x1000_2000 },
            LpSetup { l: 0, count: 70, len: 17 },
            LpSetup { l: 1, count: 1, len: 1 },
            Branch { cond: Cond::Eq, rs1: 1, rs2: 2, off: 5 },
            Branch { cond: Cond::Ne, rs1: 1, rs2: 0, off: -3 },
            Branch { cond: Cond::Lt, rs1: 9, rs2: 8, off: 2 },
            Branch { cond: Cond::Ge, rs1: 9, rs2: 8, off: -2 },
            Barrier,
            Halt,
        ];
        for i in cases {
            roundtrip(i);
        }
    }

    /// Every CSR name roundtrips through its rendering.
    #[test]
    fn every_csr_roundtrips() {
        for csr in [
            Csr::SimdFmt,
            Csr::MixSkip,
            Csr::SbLegacy,
            Csr::AStride,
            Csr::WStride,
            Csr::ARollback,
            Csr::WRollback,
            Csr::ASkip,
            Csr::WSkip,
            Csr::ABase,
            Csr::WBase,
        ] {
            roundtrip(Instr::CsrW { csr, imm: 7 });
        }
    }

    /// The satellite guarantee: disassembling the generated MatMul
    /// kernel of EVERY IsaVariant × precision point and parsing it back
    /// reproduces the instruction stream exactly — including the
    /// generator invariants the textual form relies on (post-modified
    /// ops carry no separate offset).
    #[test]
    fn generated_kernels_roundtrip_for_every_isa() {
        use crate::kernels::matmul::{gen_matmul, MatMulTask};
        use crate::kernels::requant::RequantCfg;
        for isa in IsaVariant::ALL {
            for prec in Precision::grid() {
                let task = MatMulTask {
                    m: 8,
                    n: 8,
                    k: 32,
                    prec,
                    a_base: crate::sim::TCDM_BASE,
                    a_pitch: (32usize.div_ceil(32 / prec.a_bits as usize) * 4) as u32,
                    w_base: crate::sim::TCDM_BASE + 4096,
                    w_pitch: 16,
                    out_base: crate::sim::TCDM_BASE + 8192,
                    out_pitch: 8,
                    quant: RequantCfg {
                        mult_base: crate::sim::TCDM_BASE + 12288,
                        bias_base: crate::sim::TCDM_BASE + 12544,
                        shift: 8,
                        out_bits: 8,
                    },
                };
                let prog = gen_matmul(isa, &task, 0, 1);
                assert!(!prog.is_empty(), "{isa} {prec}: empty kernel");
                for instr in &prog.instrs {
                    // the lossless-rendering invariant (module docs)
                    match *instr {
                        Instr::Lw { off, post_inc, .. }
                        | Instr::Lbu { off, post_inc, .. }
                        | Instr::Sw { off, post_inc, .. }
                        | Instr::Sb { off, post_inc, .. } => {
                            assert!(
                                post_inc == 0 || off == 0,
                                "{isa} {prec}: post-modified op with offset {instr:?}"
                            );
                        }
                        _ => {}
                    }
                    roundtrip(*instr);
                }
                // whole-listing parse (addresses + header) agrees too
                let listing = disasm_program(&prog);
                let back = parse_program(&listing).expect("listing must parse");
                assert_eq!(back, prog.instrs, "{isa} {prec}: listing roundtrip");
            }
        }
    }

    /// Property: random instructions drawn from the IR roundtrip.
    #[test]
    fn prop_random_instructions_roundtrip() {
        let fmts = [SimdFmt::Half, SimdFmt::Byte, SimdFmt::Nibble, SimdFmt::Crumb];
        proptest::check_default(
            |rng: &mut Prng| {
                let r = |rng: &mut Prng| rng.range(0, 32) as u8;
                match rng.range(0, 10) {
                    0 => Instr::Li { rd: r(rng), imm: rng.next_u32() as i32 },
                    1 => Instr::Alu {
                        op: *rng.pick(&[AluOp::Add, AluOp::Sub, AluOp::Sll, AluOp::Min]),
                        rd: r(rng),
                        rs1: r(rng),
                        rs2: r(rng),
                    },
                    2 => Instr::AluI {
                        op: *rng.pick(&[AluOp::Add, AluOp::Srl, AluOp::And, AluOp::Max]),
                        rd: r(rng),
                        rs1: r(rng),
                        imm: rng.range_i64(-2048, 2048) as i32,
                    },
                    3 => Instr::Lw {
                        rd: r(rng),
                        base: r(rng),
                        off: if rng.chance(0.5) { rng.range_i64(-64, 64) as i32 * 4 } else { 0 },
                        post_inc: 0,
                    },
                    4 => Instr::Sw {
                        rs: r(rng),
                        base: r(rng),
                        off: 0,
                        post_inc: rng.range_i64(-16, 17) as i32,
                    },
                    5 => Instr::Sdotp {
                        rd: r(rng),
                        ra: r(rng),
                        rw: r(rng),
                        a_fmt: *rng.pick(&fmts),
                        w_fmt: *rng.pick(&fmts),
                        sub: rng.range(0, 8) as u8,
                    },
                    6 => Instr::MlSdotp {
                        acc: r(rng),
                        a_slot: 4 + rng.range(0, 2) as u8,
                        w_slot: rng.range(0, 4) as u8,
                        a_fmt: *rng.pick(&fmts),
                        w_fmt: *rng.pick(&fmts),
                        sub: rng.range(0, 8) as u8,
                        upd: if rng.chance(0.5) {
                            MlUpdate::None
                        } else {
                            MlUpdate::Load {
                                ch: *rng.pick(&[MlChannel::Act, MlChannel::Wgt]),
                                slot: rng.range(0, 6) as u8,
                            }
                        },
                    },
                    7 => Instr::LpSetup {
                        l: rng.range(0, 2) as u8,
                        count: rng.next_u32() % 1000 + 1,
                        len: (rng.range(1, 100)) as u16,
                    },
                    8 => Instr::Clipu { rd: r(rng), rs1: r(rng), bits: rng.range(1, 9) as u8 },
                    _ => Instr::Branch {
                        cond: *rng.pick(&[Cond::Eq, Cond::Ne, Cond::Lt, Cond::Ge]),
                        rs1: r(rng),
                        rs2: r(rng),
                        off: rng.range_i64(-100, 100) as i32,
                    },
                }
            },
            |i| {
                let text = disasm(i);
                if parse(&text) == Some(*i) {
                    Ok(())
                } else {
                    Err(format!("`{text}` parsed to {:?}", parse(&text)))
                }
            },
        );
    }
}
