//! Tier-1 suite for the benchmark-artifact pipeline: JSON round-trip
//! and schema stability of `BenchArtifact`, `regress` comparison
//! semantics (exact match / in-tolerance / failing drift / missing
//! metric / pending baseline), and the `MetricSource` impls that feed
//! the suites — including an injected cycle regression that must fail
//! the gate with a rendered per-metric drift table, which is the CI
//! `perf-gate` job's failure path exercised hermetically.

use flexv::qnn::{Layer, Network, QTensor};
use flexv::report::artifact::{
    BenchArtifact, Json, MetricKind, MetricRow, MetricSource, RunMeta, SCHEMA_VERSION,
};
use flexv::report::regress::{compare, paper_distance, DriftStatus, Tolerance};
use flexv::serve::{Engine, ServeConfig, TraceItem};
use flexv::util::Prng;

fn sample_artifact() -> BenchArtifact {
    let mut a = BenchArtifact::new(
        "kernels",
        RunMeta {
            git_rev: "deadbeef0123".into(),
            seed: 0x7AB3,
            quick: true,
            sim: "8 cores, 128 kB TCDM, 16 banks".into(),
        },
    );
    a.rows = vec![
        MetricRow::exact("kernels/matmul/flexv/a2w2/cycles", 42_123.0, "cycles"),
        MetricRow::exact("kernels/matmul/flexv/a2w2/mac_per_cycle", 88.25, "MAC/cycle")
            .with_paper(91.5),
        MetricRow::analog("kernels/matmul/flexv/a2w2/tops_per_watt", 3.11, "TOPS/W")
            .with_paper(3.26),
    ];
    a
}

// ---------------------------------------------------------------------------
// Schema round-trip and stability.
// ---------------------------------------------------------------------------

#[test]
fn serialize_parse_equal() {
    let a = sample_artifact();
    let text = a.to_json();
    let b = BenchArtifact::from_json(&text).expect("round-trip parse");
    assert_eq!(a, b);
    // and the bytes themselves are deterministic
    assert_eq!(text, b.to_json());
}

#[test]
fn float_values_roundtrip_bit_exactly() {
    // Shortest-round-trip formatting: awkward fractions survive the
    // JSON round trip down to the last bit (what lets Exact rows gate
    // with --tol-cycles 0).
    let mut a = BenchArtifact::new("s", RunMeta::default());
    for (i, v) in [0.1, 1.0 / 3.0, 2.0_f64.powi(-40), 91.5, 12_345_678_901_234.0]
        .into_iter()
        .enumerate()
    {
        a.rows.push(MetricRow::exact(format!("s/m{i}"), v, ""));
    }
    let b = BenchArtifact::from_json(&a.to_json()).unwrap();
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.value.to_bits(), rb.value.to_bits(), "{}", ra.id);
    }
}

#[test]
fn unknown_fields_are_ignored() {
    // A future writer may add fields; this parser must skip them.
    let text = r#"{
      "schema": "flexv-bench-artifact",
      "schema_version": 1,
      "suite": "kernels",
      "flux_capacitance": [1, 2, 3],
      "meta": {"git_rev": "abc", "seed": 5, "quick": true, "sim": "x", "extra": null},
      "rows": [
        {"id": "kernels/a", "value": 7, "unit": "cycles", "kind": "exact", "note": "hi"}
      ]
    }"#;
    let a = BenchArtifact::from_json(text).expect("unknown fields tolerated");
    assert_eq!(a.suite, "kernels");
    assert_eq!(a.meta.seed, 5);
    assert_eq!(a.rows.len(), 1);
    assert_eq!(a.rows[0].value, 7.0);
    assert_eq!(a.rows[0].kind, MetricKind::Exact);
}

#[test]
fn newer_schema_version_is_rejected() {
    let newer = format!(
        r#"{{"schema_version": {}, "suite": "x", "rows": []}}"#,
        SCHEMA_VERSION + 1
    );
    let err = BenchArtifact::from_json(&newer).unwrap_err();
    assert!(err.contains("newer"), "unhelpful error: {err}");
    // the current version (and, by construction, older ones) parse
    let ok = format!(r#"{{"schema_version": {SCHEMA_VERSION}, "suite": "x", "rows": []}}"#);
    assert!(BenchArtifact::from_json(&ok).is_ok());
}

#[test]
fn malformed_documents_are_rejected() {
    for bad in [
        "",
        "not json",
        r#"{"schema_version": 1}"#,                        // no suite
        r#"{"suite": "x", "rows": []}"#,                   // no version
        r#"{"schema_version": 1, "suite": "x"}"#,          // no rows
        r#"{"schema_version": 1, "suite": "x", "rows": [{"value": 1}]}"#, // row without id
    ] {
        assert!(BenchArtifact::from_json(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn json_value_api_covers_the_schema() {
    let v = Json::parse(r#"{"a": [true, null, "s"], "n": -2.5e3}"#).unwrap();
    assert_eq!(v.get("n").unwrap().as_f64(), Some(-2500.0));
    assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(v.get("missing"), None);
    // u64 accessor refuses fractions and negatives
    assert_eq!(Json::Num(1.5).as_u64(), None);
    assert_eq!(Json::Num(-1.0).as_u64(), None);
    assert_eq!(Json::Num(3.0).as_u64(), Some(3));
}

// ---------------------------------------------------------------------------
// regress semantics.
// ---------------------------------------------------------------------------

#[test]
fn identical_runs_pass_with_zero_tolerance() {
    let a = sample_artifact();
    let rep = compare(&a, &a.clone(), &Tolerance::default());
    assert!(!rep.failed());
    assert_eq!(rep.count(DriftStatus::Match), a.rows.len());
}

#[test]
fn injected_cycle_regression_fails_the_gate() {
    // The satellite check: a deliberate cycle regression must fail
    // `regress` and render a per-metric drift table naming the metric.
    let base = sample_artifact();
    let mut cur = base.clone();
    let row = cur
        .rows
        .iter_mut()
        .find(|r| r.id.ends_with("/cycles"))
        .expect("sample has a cycles row");
    row.value += 257.0; // the injected regression
    let rep = compare(&cur, &base, &Tolerance::default());
    assert!(rep.failed(), "a +257-cycle regression must fail --tol-cycles 0");
    assert_eq!(rep.count(DriftStatus::Drift), 1);
    let table = rep.render();
    assert!(
        table.contains("kernels/matmul/flexv/a2w2/cycles") && table.contains("DRIFT"),
        "drift table must name the regressed metric:\n{table}"
    );
    assert!(table.contains("FAIL"), "{table}");
}

#[test]
fn analog_rows_get_a_tolerance_band_exact_rows_do_not() {
    let base = sample_artifact();
    let mut cur = base.clone();
    // +1% on the analog TOPS/W row: inside the default 2% band
    let eff = cur.rows.iter_mut().find(|r| r.kind == MetricKind::Analog).unwrap();
    eff.value *= 1.01;
    let rep = compare(&cur, &base, &Tolerance::default());
    assert!(!rep.failed());
    assert_eq!(rep.count(DriftStatus::InTolerance), 1);
    // the same 1% on an exact cycles row fails at --tol-cycles 0
    let mut cur2 = base.clone();
    let cyc = cur2.rows.iter_mut().find(|r| r.id.ends_with("/cycles")).unwrap();
    cyc.value *= 1.01;
    assert!(compare(&cur2, &base, &Tolerance::default()).failed());
    // ...and passes once --tol-cycles covers the delta
    let tol = Tolerance { exact_abs: 1_000.0, analog_frac: 0.02 };
    assert!(!compare(&cur2, &base, &tol).failed());
}

#[test]
fn vanished_metric_fails_new_metric_reports_only() {
    let base = sample_artifact();
    let mut cur = base.clone();
    cur.rows.remove(0);
    cur.rows.push(MetricRow::exact("kernels/new/metric", 1.0, ""));
    let rep = compare(&cur, &base, &Tolerance::default());
    assert!(rep.failed(), "a metric that vanished must fail the gate");
    assert_eq!(rep.count(DriftStatus::MissingInCurrent), 1);
    assert_eq!(rep.count(DriftStatus::NewInCurrent), 1);
}

#[test]
fn pending_baseline_fails_the_gate_without_drift() {
    let mut base = sample_artifact();
    base.pending = true;
    // wildly different current values: no drift rows (targets are from
    // the paper, not measurements) — but the gate fails because the
    // suite is unpinned and only `regress --bless` clears that.
    let mut cur = sample_artifact();
    for r in &mut cur.rows {
        r.value *= 3.0;
    }
    let rep = compare(&cur, &base, &Tolerance::default());
    assert!(rep.failed(), "pending baseline must fail a non-bless run");
    assert_eq!(rep.count(DriftStatus::Drift), 0);
    assert!(rep.pending_baseline);
    assert!(rep.render().contains("PENDING"));
    assert!(rep.render().contains("FAIL"));
    // the pending flag round-trips through JSON
    let b2 = BenchArtifact::from_json(&base.to_json()).unwrap();
    assert!(b2.pending);
}

#[test]
fn paper_distance_table_lists_only_referenced_rows() {
    let a = sample_artifact();
    let t = paper_distance(&a).expect("sample carries paper refs");
    assert!(t.contains("91.5") && t.contains("mac_per_cycle"), "{t}");
    assert!(!t.contains("kernels/matmul/flexv/a2w2/cycles"), "{t}");
}

// ---------------------------------------------------------------------------
// MetricSource impls (tiny workloads only — tier-1 stays fast).
// ---------------------------------------------------------------------------

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [8, 8, 8], 8);
    net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

/// Run a small 2-model fleet and return its metric rows.
fn tiny_fleet_rows(workers: usize) -> Vec<MetricRow> {
    let cfg = ServeConfig {
        shards: 2,
        n_cores: 4,
        queue_capacity: 32,
        max_batch: 4,
        workers,
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(cfg);
    let a = eng.register(tiny("art-a", 21));
    let b = eng.register(tiny("art-b", 22));
    let mut rng = Prng::new(23);
    let trace: Vec<TraceItem> = (0..6)
        .map(|i| TraceItem {
            at: i as u64 * 90,
            model: if i % 3 == 0 { b } else { a },
            class: 0,
            priority: 0,
            deadline: None,
            input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
        })
        .collect();
    eng.run_trace(trace).metric_rows()
}

#[test]
fn fleet_metric_rows_are_simulated_only_unique_and_worker_independent() {
    let rows = tiny_fleet_rows(1);
    assert!(rows.len() > 20, "expected a full fleet row set, got {}", rows.len());
    // unique ids (the regress join key)
    let mut ids: Vec<&str> = rows.iter().map(|r| r.id.as_str()).collect();
    ids.sort_unstable();
    let n = ids.len();
    ids.dedup();
    assert_eq!(n, ids.len(), "duplicate metric ids");
    // host-side counters must never appear
    assert!(
        rows.iter().all(|r| !r.id.contains("fastpath")),
        "fast-path counters are host-side and must not be artifact rows"
    );
    // per-model and per-class breakdowns present, with sanitized ids
    assert!(rows.iter().any(|r| r.id == "serve/model/art-a/p99_cycles"));
    assert!(rows.iter().any(|r| r.id.starts_with("serve/class/")));
    // energy is the only analog family in the serve suite
    for r in &rows {
        if r.kind == MetricKind::Analog {
            assert!(r.id.ends_with("/energy_uj"), "unexpected analog row {}", r.id);
        }
    }
    // worker count must not move a single row (the determinism contract
    // the perf gate leans on)
    let rows4 = tiny_fleet_rows(4);
    assert_eq!(rows.len(), rows4.len());
    for (x, y) in rows.iter().zip(&rows4) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.value.to_bits(), y.value.to_bits(), "{} moved with workers", x.id);
    }
}

#[test]
fn tuned_model_metrics_rows_are_consistent() {
    use flexv::dory::autotune::{tune_network, TuneConfig, TunedModelMetrics};
    use flexv::dory::MemBudget;
    use flexv::isa::IsaVariant;
    let net = tiny("tune-art", 24);
    let tuning =
        tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &TuneConfig::default());
    let rows = TunedModelMetrics { model: "tune-art", tuning: &tuning }.metric_rows();
    let get = |suffix: &str| {
        rows.iter()
            .find(|r| r.id == format!("autotune/tune-art/{suffix}"))
            .unwrap_or_else(|| panic!("missing row {suffix}"))
            .value
    };
    assert_eq!(get("layers"), net.nodes.len() as f64);
    assert!(get("tuned_cycles") <= get("default_cycles"), "tuner can never regress");
    assert!(get("improved_layers") <= get("layers"));
    assert!(rows.iter().all(|r| r.kind == MetricKind::Exact));
    // rows drop into an artifact without id collisions
    let mut art = BenchArtifact::new("autotune", RunMeta::default());
    art.push_source(&TunedModelMetrics { model: "tune-art", tuning: &tuning });
    assert_eq!(art.rows.len(), rows.len());
    let round = BenchArtifact::from_json(&art.to_json()).unwrap();
    assert_eq!(art, round);
}
