//! Deterministic cycle-domain structured tracing.
//!
//! The trace clock is the **simulated cycle counter** ([`crate::sim::Cluster::cycle`]
//! for the sim layer, the serve engine's discrete-event clock for the
//! fleet layer), never the host clock. Because every simulated number in
//! this crate is a pure function of its inputs (see the determinism
//! contract in [`crate::serve`]), a recorded trace inherits that
//! property: the exported bytes are identical for any worker count and
//! any fast-path setting, which makes traces *testable determinism
//! artifacts* (`rust/tests/trace_determinism.rs` and the CI trace gate
//! byte-diff them).
//!
//! Two clock domains coexist and are kept apart by [`Scope`]:
//!
//! - [`Scope::Sim`] events carry simulated-cycle timestamps and are the
//!   deterministic payload. The Chrome exporter ([`chrome`]) emits only
//!   these by default.
//! - [`Scope::Host`] events mark host-side machinery (fast-path
//!   record/replay outcomes, cross-checks). They are deterministic in
//!   *time* (stamped with the window's start cycle) but not in *kind*
//!   across fast-path settings — a window that records on one run
//!   replays on the next — so the default export excludes them.
//!
//! Instrumentation points build events only when a sink is attached
//! (`Cluster::tracer` is an `Option`), so the disabled cost is one
//! branch and zero simulated cycles — asserted by
//! `benches/serve_throughput.rs`. The serve layer does not sink events
//! from shard worker threads at all: [`crate::serve::Engine::build_trace`]
//! reconstructs the fleet timeline *post hoc* from the deterministic
//! completion/shed/occupancy records, so tracing can never perturb
//! scheduling.
//!
//! Submodules: [`chrome`] (Perfetto-loadable trace-event JSON),
//! [`profile`] (per-layer profile report), [`serve`] (fleet trace
//! builder).

pub mod chrome;
pub mod profile;
pub mod serve;

/// Clock domain of an event (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scope {
    /// Simulated-cycle domain: deterministic, exported by default.
    Sim,
    /// Host-side machinery (fast-path outcomes, cross-checks): excluded
    /// from the default export because record-vs-replay varies with the
    /// fast-path setting.
    Host,
}

/// One argument value attached to an event.
#[derive(Clone, Debug, PartialEq)]
pub enum Arg {
    U64(u64),
    F64(f64),
    Str(String),
}

/// A (process, thread) pair identifying one timeline track. The Chrome
/// exporter maps `pid` to a shard (or the single cluster) and `tid` to
/// a core / DMA / fleet lane within it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Track {
    pub pid: u32,
    pub tid: u32,
}

/// Shorthand constructor for a [`Track`].
pub const fn track(pid: u32, tid: u32) -> Track {
    Track { pid, tid }
}

/// Event payload: what kind of mark this is on its track.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Payload {
    /// A duration event covering `[at, at + dur]` cycles (`"X"` in the
    /// Chrome trace-event format). `dur` is unsigned, so `end >= begin`
    /// holds by construction; [`check_well_nested`] additionally rejects
    /// overflowing ends.
    Span { dur: u64 },
    /// A point event (`"i"`).
    Instant,
    /// A counter sample (`"C"`): the track plots `value` over time.
    Counter { value: f64 },
}

/// One trace event, stamped in simulated cycles.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub name: String,
    pub scope: Scope,
    pub track: Track,
    /// Timestamp in simulated cycles.
    pub at: u64,
    pub payload: Payload,
    pub args: Vec<(&'static str, Arg)>,
}

impl Event {
    /// Span duration (0 for instants and counters) — the canonical-order
    /// tie-break so enclosing spans sort before their children.
    fn dur(&self) -> u64 {
        match self.payload {
            Payload::Span { dur } => dur,
            _ => 0,
        }
    }
}

/// Where instrumentation points deliver events. The default
/// implementation contract is [`NopSink`]: `enabled()` lets callers skip
/// building events entirely when nothing records them.
pub trait TraceSink {
    /// Record one event.
    fn event(&mut self, ev: Event);
    /// Whether delivered events are kept. Instrumentation points should
    /// branch on this (or on an `Option<Recorder>` being `Some`) before
    /// constructing events.
    fn enabled(&self) -> bool {
        true
    }
}

/// The zero-overhead default sink: drops everything and reports itself
/// disabled, so instrumentation never builds events for it.
#[derive(Clone, Copy, Debug, Default)]
pub struct NopSink;

impl NopSink {
    pub fn new() -> Self {
        NopSink
    }
}

impl TraceSink for NopSink {
    fn event(&mut self, _ev: Event) {}
    fn enabled(&self) -> bool {
        false
    }
}

/// The recording sink: an in-memory event list plus process/thread
/// naming metadata, exported by [`chrome::to_chrome_json`].
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    events: Vec<Event>,
    /// `(pid, name)` process-naming metadata, first name wins.
    processes: Vec<(u32, String)>,
    /// `(pid, tid, name)` thread-naming metadata, first name wins.
    threads: Vec<(u32, u32, String)>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Name a process track (first call per `pid` wins — repeat calls
    /// from per-window instrumentation are cheap no-ops).
    pub fn name_process(&mut self, pid: u32, name: impl Into<String>) {
        if !self.processes.iter().any(|(p, _)| *p == pid) {
            self.processes.push((pid, name.into()));
        }
    }

    /// Name a thread track (first call per `(pid, tid)` wins).
    pub fn name_thread(&mut self, t: Track, name: impl Into<String>) {
        if !self.threads.iter().any(|(p, i, _)| (*p, *i) == (t.pid, t.tid)) {
            self.threads.push((t.pid, t.tid, name.into()));
        }
    }

    /// Record a duration event covering `[at, at + dur]`.
    pub fn span(
        &mut self,
        scope: Scope,
        t: Track,
        name: impl Into<String>,
        at: u64,
        dur: u64,
        args: Vec<(&'static str, Arg)>,
    ) {
        self.events.push(Event {
            name: name.into(),
            scope,
            track: t,
            at,
            payload: Payload::Span { dur },
            args,
        });
    }

    /// Record a point event.
    pub fn instant(
        &mut self,
        scope: Scope,
        t: Track,
        name: impl Into<String>,
        at: u64,
        args: Vec<(&'static str, Arg)>,
    ) {
        self.events.push(Event {
            name: name.into(),
            scope,
            track: t,
            at,
            payload: Payload::Instant,
            args,
        });
    }

    /// Record a counter sample.
    pub fn counter(
        &mut self,
        scope: Scope,
        t: Track,
        name: impl Into<String>,
        at: u64,
        value: f64,
    ) {
        self.events.push(Event {
            name: name.into(),
            scope,
            track: t,
            at,
            payload: Payload::Counter { value },
            args: Vec::new(),
        });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn processes(&self) -> &[(u32, String)] {
        &self.processes
    }

    pub fn threads(&self) -> &[(u32, u32, String)] {
        &self.threads
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Sort events into the canonical export order — by track, then
    /// timestamp, with longer spans first at equal timestamps so
    /// enclosing spans precede their children; naming metadata sorts by
    /// id. Stable, so ties keep emission order. Idempotent: exporting a
    /// canonicalized recorder twice yields identical bytes.
    pub fn canonicalize(&mut self) {
        self.processes.sort();
        self.threads.sort();
        self.events.sort_by(|a, b| {
            (a.track, a.at).cmp(&(b.track, b.at)).then_with(|| b.dur().cmp(&a.dur()))
        });
    }
}

impl TraceSink for Recorder {
    fn event(&mut self, ev: Event) {
        self.events.push(ev);
    }
}

/// Check the structural soundness of recorded span events: per
/// `(pid, tid)` track, spans must be pairwise nested or disjoint
/// (touching endpoints count as disjoint), and every span end must be
/// representable (`at + dur` must not overflow — `end >= begin` then
/// holds by construction). Instants and counters are ignored. Returns
/// the first violation found.
pub fn check_well_nested(events: &[Event]) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut tracks: BTreeMap<Track, Vec<(u64, u64, &str)>> = BTreeMap::new();
    for ev in events {
        if let Payload::Span { dur } = ev.payload {
            let end = ev
                .at
                .checked_add(dur)
                .ok_or_else(|| format!("span '{}' at {} overflows u64", ev.name, ev.at))?;
            tracks.entry(ev.track).or_default().push((ev.at, end, &ev.name));
        }
    }
    for (t, mut spans) in tracks {
        spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut stack: Vec<(u64, u64)> = Vec::new();
        for (b, e, name) in spans {
            while stack.last().is_some_and(|&(_, pe)| pe <= b) {
                stack.pop();
            }
            if let Some(&(pb, pe)) = stack.last() {
                if e > pe {
                    return Err(format!(
                        "track ({},{}): span '{name}' [{b},{e}] straddles enclosing [{pb},{pe}]",
                        t.pid, t.tid
                    ));
                }
            }
            stack.push((b, e));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_ev(tid: u32, at: u64, dur: u64) -> Event {
        Event {
            name: format!("s{at}"),
            scope: Scope::Sim,
            track: track(0, tid),
            at,
            payload: Payload::Span { dur },
            args: vec![],
        }
    }

    #[test]
    fn nop_sink_is_disabled() {
        let mut s = NopSink::new();
        assert!(!s.enabled());
        s.event(span_ev(0, 0, 1)); // dropped
    }

    #[test]
    fn recorder_collects_and_names_first_wins() {
        let mut r = Recorder::new();
        assert!(r.is_empty());
        r.name_process(0, "cluster");
        r.name_process(0, "ignored");
        r.name_thread(track(0, 1), "core0");
        r.name_thread(track(0, 1), "ignored");
        r.span(Scope::Sim, track(0, 1), "k", 5, 10, vec![("macs", Arg::U64(7))]);
        r.instant(Scope::Host, track(0, 0), "i", 5, vec![]);
        r.counter(Scope::Sim, track(0, 0), "c", 6, 2.5);
        assert_eq!(r.len(), 3);
        assert_eq!(r.processes(), &[(0, "cluster".to_string())]);
        assert_eq!(r.threads(), &[(0, 1, "core0".to_string())]);
        assert_eq!(r.events()[0].args, vec![("macs", Arg::U64(7))]);
    }

    #[test]
    fn canonicalize_orders_enclosing_spans_first_and_is_idempotent() {
        let mut r = Recorder::new();
        // child emitted before its enclosing span (the sim layer emits
        // window spans during the run, the layer span after it)
        r.span(Scope::Sim, track(0, 0), "child", 10, 5, vec![]);
        r.span(Scope::Sim, track(0, 0), "layer", 10, 50, vec![]);
        r.span(Scope::Sim, track(0, 0), "early", 0, 3, vec![]);
        r.canonicalize();
        let names: Vec<&str> = r.events().iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["early", "layer", "child"]);
        let once: Vec<Event> = r.events().to_vec();
        r.canonicalize();
        assert_eq!(r.events(), &once[..]);
    }

    #[test]
    fn well_nested_accepts_nesting_and_touching() {
        let evs = vec![
            span_ev(0, 0, 100),
            span_ev(0, 0, 40),  // nested, shared begin
            span_ev(0, 40, 60), // nested, touching the previous child
            span_ev(0, 100, 5), // disjoint, touching the enclosing end
            span_ev(1, 50, 500), // other track: independent
        ];
        check_well_nested(&evs).unwrap();
    }

    #[test]
    fn well_nested_rejects_straddling_spans() {
        let evs = vec![span_ev(0, 0, 10), span_ev(0, 5, 10)];
        let err = check_well_nested(&evs).unwrap_err();
        assert!(err.contains("straddles"), "{err}");
    }

    #[test]
    fn well_nested_rejects_overflowing_end() {
        let evs = vec![span_ev(0, u64::MAX, 2)];
        assert!(check_well_nested(&evs).unwrap_err().contains("overflows"));
    }
}
