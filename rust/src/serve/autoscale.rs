//! Elastic shard pool: scale the active shard count to the offered load.
//!
//! A static fleet sized for the burst peak idles through the valleys;
//! one sized for the average melts under bursts. The autoscaler walks
//! the active shard count between a configured `min` and `max` from two
//! deterministic signals observed **between dispatch rounds on the
//! sequential engine thread**:
//!
//! - **queue pressure** (scale up): after arrivals are admitted and
//!   unmeetable requests shed, the target active count is the busy
//!   shards plus one shard per `up_queue_per_shard` queued requests —
//!   i.e. work waiting behind busy shards wakes parked shards in the
//!   same dispatch round it queued (jumping straight to the needed
//!   count — burst response is one round, not one shard per round, so
//!   an elastic pool tracks a static max-size fleet's schedule through
//!   a burst). Scale-up is **not** gated by the cooldown: an SLO breach
//!   now outweighs churn.
//! - **idleness** (scale down): when the queue is empty and an active
//!   shard has been idle for `idle_cycles_down`, it is parked — at most
//!   one shard per `cooldown_cycles`, so draining a valley doesn't
//!   collapse the fleet just before the next burst.
//!
//! **Cold-load cost.** Parking a shard evicts its L2 model image
//! ([`super::Shard::park`] clears residency): the next batch after a
//! wake pays the full L3→L2 weight-streaming switch cost, exactly the
//! cost a cold static shard pays on first use. Nothing else about a
//! parked shard is retained or lost — its cluster (and the fleet-shared
//! fast-path window cache) survives, because parking is a scheduling
//! decision, not a teardown.
//!
//! **Determinism.** Decisions depend only on (simulated clock, queue
//! depth, shard busy/idle state) — all products of the sequential
//! scheduling half of the engine's determinism contract — so the
//! scaling timeline (and therefore every completion) is bit-identical
//! for any `workers` count and fast-path setting
//! (`rust/tests/serve_workload.rs`).

use super::shard::Shard;

/// Elastic-pool knobs (`serve-bench --autoscale min:max`).
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Never park below this many active shards (≥ 1).
    pub min_shards: usize,
    /// Never wake above this many active shards (≤ `ServeConfig::shards`).
    pub max_shards: usize,
    /// Queued requests per active shard that trigger a wake.
    pub up_queue_per_shard: f64,
    /// Idle cycles after which an active shard becomes parkable.
    pub idle_cycles_down: u64,
    /// Minimum cycles between two scale-*down* actions (scale-up is
    /// deliberately immediate; see module docs).
    pub cooldown_cycles: u64,
}

impl AutoscaleConfig {
    /// Defaults for a `min:max` range: wake on any queued backlog beyond
    /// one request per active shard; park after ~40 ms idle at 250 MHz;
    /// at most one park per 4 ms.
    pub fn range(min_shards: usize, max_shards: usize) -> Self {
        assert!(min_shards >= 1 && min_shards <= max_shards, "need 1 <= min <= max");
        AutoscaleConfig {
            min_shards,
            max_shards,
            up_queue_per_shard: 1.0,
            idle_cycles_down: 10_000_000,
            cooldown_cycles: 1_000_000,
        }
    }
}

/// One scaling action, recorded for the occupancy timeline.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScaleAction {
    /// Woke `n` shards.
    Up(usize),
    /// Parked one shard.
    Down,
}

/// The autoscaler's mutable state (cooldown bookkeeping + counters).
#[derive(Clone, Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    /// Cycle of the last scale-down (cooldown reference).
    last_down: Option<u64>,
    /// Shards woken over the run.
    pub ups: u64,
    /// Shards parked over the run.
    pub downs: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler { cfg, last_down: None, ups: 0, downs: 0 }
    }

    /// Decide and apply one round of scaling at simulated cycle `now`
    /// given the post-shed queue depth. Mutates shard active flags via
    /// [`Shard::wake`]/[`Shard::park`] and returns the action taken, if
    /// any. Runs on the engine thread between dispatch rounds — never
    /// concurrently with shard execution.
    ///
    /// `max_active` is the power-cap clamp: under a fleet power cap the
    /// engine passes how many shards the cap can power at the lowest
    /// operating point, and the scaler never wakes beyond it (waking a
    /// shard the dispatcher could never feed would only burn leakage).
    /// It clamps the ceiling, not the floor — dispatch-time admission is
    /// what actually enforces the cap.
    pub fn step(
        &mut self,
        now: u64,
        queue_len: usize,
        shards: &mut [Shard],
        max_active: Option<usize>,
    ) -> Option<ScaleAction> {
        let max =
            self.cfg.max_shards.min(shards.len()).min(max_active.unwrap_or(usize::MAX)).max(1);
        let min = self.cfg.min_shards.min(max);
        let active = shards.iter().filter(|s| s.active).count();

        // Scale up: wake enough parked shards (lowest index first, so
        // the choice is deterministic) to serve the in-flight work plus
        // one shard per up_queue_per_shard queued requests. Failed
        // shards (fault injection, [`Shard::fail`]) are parked too but
        // must stay down until they recover, so they are never victims
        // of a wake.
        let per = self.cfg.up_queue_per_shard.max(f64::MIN_POSITIVE);
        let busy = shards.iter().filter(|s| s.active && !s.is_free(now)).count();
        let needed = busy + (queue_len as f64 / per).ceil() as usize;
        let target = needed.clamp(min, max);
        if target > active {
            let mut woken = 0;
            for s in shards.iter_mut() {
                if active + woken >= target {
                    break;
                }
                if !s.active && !s.is_failed(now) {
                    s.wake();
                    woken += 1;
                }
            }
            if woken > 0 {
                self.ups += woken as u64;
                return Some(ScaleAction::Up(woken));
            }
            return None;
        }

        // Scale down: one idle shard per cooldown window, only when the
        // queue is drained. Park the highest-index idle shard so shard 0
        // stays the stable core of the fleet.
        if queue_len == 0 && active > min {
            let cooled = self
                .last_down
                .map_or(true, |t| now.saturating_sub(t) >= self.cfg.cooldown_cycles);
            if cooled {
                let victim = shards
                    .iter_mut()
                    .rev()
                    .find(|s| s.active && s.idle_cycles(now) >= self.cfg.idle_cycles_down);
                if let Some(s) = victim {
                    s.park();
                    self.downs += 1;
                    self.last_down = Some(now);
                    return Some(ScaleAction::Down);
                }
            }
        }
        None
    }

    /// Earliest future cycle at which a scale-down could fire, assuming
    /// the queue stays empty and no new work lands: the soonest any
    /// active shard reaches `idle_cycles_down`, pushed past the cooldown
    /// window. `None` when the pool is already at its floor. The engine
    /// uses this as a discrete wake event so long valleys actually park
    /// shards instead of being skipped by the event-driven clock.
    pub fn next_down_event(&self, shards: &[Shard]) -> Option<u64> {
        let max = self.cfg.max_shards.min(shards.len());
        let min = self.cfg.min_shards.min(max);
        let active = shards.iter().filter(|s| s.active).count();
        if active <= min {
            return None;
        }
        let earliest = shards
            .iter()
            .filter(|s| s.active)
            .map(|s| s.busy_until.saturating_add(self.cfg.idle_cycles_down))
            .min()?;
        Some(match self.last_down {
            Some(t) => earliest.max(t.saturating_add(self.cfg.cooldown_cycles)),
            None => earliest,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::CoreFidelity;

    fn fleet(n: usize, active: usize) -> Vec<Shard> {
        (0..n)
            .map(|i| {
                let mut s = Shard::new(i, 2, false, None, CoreFidelity::Fast);
                if i >= active {
                    s.park();
                }
                s
            })
            .collect()
    }

    fn active_ids(shards: &[Shard]) -> Vec<usize> {
        shards.iter().filter(|s| s.active).map(|s| s.id).collect()
    }

    #[test]
    fn wakes_enough_shards_for_the_backlog_in_one_step() {
        let mut shards = fleet(4, 1);
        let mut a = Autoscaler::new(AutoscaleConfig::range(1, 4));
        // 3 queued requests at 1 request/shard => target 3 active
        assert_eq!(a.step(0, 3, &mut shards, None), Some(ScaleAction::Up(2)));
        assert_eq!(active_ids(&shards), vec![0, 1, 2]);
        assert_eq!(a.ups, 2);
        // already at target: no action
        assert_eq!(a.step(10, 3, &mut shards, None), None);
        // deeper backlog saturates at max
        assert_eq!(a.step(20, 100, &mut shards, None), Some(ScaleAction::Up(1)));
        assert_eq!(active_ids(&shards), vec![0, 1, 2, 3]);
    }

    #[test]
    fn parks_idle_shards_one_per_cooldown_down_to_min() {
        let mut shards = fleet(3, 3);
        let mut cfg = AutoscaleConfig::range(1, 3);
        cfg.idle_cycles_down = 100;
        cfg.cooldown_cycles = 1000;
        let mut a = Autoscaler::new(cfg);
        // not yet idle long enough
        assert_eq!(a.step(50, 0, &mut shards, None), None);
        // highest-index idle shard parks first
        assert_eq!(a.step(200, 0, &mut shards, None), Some(ScaleAction::Down));
        assert_eq!(active_ids(&shards), vec![0, 1]);
        // cooldown blocks the next park
        assert_eq!(a.step(300, 0, &mut shards, None), None);
        assert_eq!(a.step(1300, 0, &mut shards, None), Some(ScaleAction::Down));
        assert_eq!(active_ids(&shards), vec![0]);
        // never below min
        assert_eq!(a.step(99_999, 0, &mut shards, None), None);
        assert_eq!((a.ups, a.downs), (0, 2));
    }

    #[test]
    fn parked_shard_loses_residency_and_pays_cold_load_on_wake() {
        let mut s = Shard::new(0, 2, false, None, CoreFidelity::Fast);
        s.resident_model = Some(1);
        s.park();
        assert!(!s.active);
        assert_eq!(s.resident_model, None, "parking evicts the L2 image");
        s.wake();
        assert!(s.active);
        assert_eq!(s.resident_model, None, "wake is cold: next batch pays the switch");
    }

    #[test]
    fn failed_shards_are_never_woken() {
        let mut shards = fleet(3, 1);
        shards[1].fail(10_000);
        let mut a = Autoscaler::new(AutoscaleConfig::range(1, 3));
        // deep backlog: only the healthy parked shard wakes
        assert_eq!(a.step(0, 100, &mut shards, None), Some(ScaleAction::Up(1)));
        assert_eq!(active_ids(&shards), vec![0, 2]);
        // after recovery the shard is a wake candidate again
        shards[1].recover();
        shards[1].park();
        assert_eq!(a.step(11_000, 100, &mut shards, None), Some(ScaleAction::Up(1)));
        assert_eq!(active_ids(&shards), vec![0, 1, 2]);
    }

    #[test]
    fn busy_shards_are_not_parked() {
        let mut shards = fleet(2, 2);
        shards[1].busy_until = 1_000_000; // mid-batch
        let mut cfg = AutoscaleConfig::range(1, 2);
        cfg.idle_cycles_down = 10;
        cfg.cooldown_cycles = 0;
        let mut a = Autoscaler::new(cfg);
        // shard 1 is busy (idle_cycles == 0); shard 0 is idle => shard 0
        // parks even though higher-index shards are preferred victims
        assert_eq!(a.step(500_000, 0, &mut shards, None), Some(ScaleAction::Down));
        assert_eq!(active_ids(&shards), vec![1]);
    }

    /// A fleet power cap clamps scale-up: the engine passes how many
    /// shards the cap can power at the lowest operating point, and the
    /// scaler never wakes beyond it — but a raised cap frees the rest.
    #[test]
    fn power_cap_clamps_scale_up() {
        let mut shards = fleet(4, 1);
        let mut a = Autoscaler::new(AutoscaleConfig::range(1, 4));
        // deep backlog, but the cap only powers 2 shards
        assert_eq!(a.step(0, 100, &mut shards, Some(2)), Some(ScaleAction::Up(1)));
        assert_eq!(active_ids(&shards), vec![0, 1]);
        assert_eq!(a.step(10, 100, &mut shards, Some(2)), None);
        // raising the cap frees the rest of the pool
        assert_eq!(a.step(20, 100, &mut shards, None), Some(ScaleAction::Up(2)));
        assert_eq!(active_ids(&shards), vec![0, 1, 2, 3]);
        // a cap below the floor still keeps one shard serving
        let mut one = fleet(2, 1);
        let mut b = Autoscaler::new(AutoscaleConfig::range(1, 2));
        assert_eq!(b.step(0, 100, &mut one, Some(0)), None);
        assert_eq!(active_ids(&one), vec![0]);
    }
}
