//! Dynamic batching policy.
//!
//! When a shard frees up, the batcher picks a **lead** request from the
//! queue (priority, FIFO, shard-affinity — see
//! [`RequestQueue::pop_lead`]) and coalesces up to `max_batch - 1` more
//! queued requests for the same model behind it. A batch shares one plan
//! lookup and at most one model switch: the L3→L2 weight streaming and
//! the warm tile-timing memo are amortized over every member, exactly the
//! way PULP-NN amortizes im2col/packing setup across kernel invocations.
//!
//! Batch formation always runs on the engine thread, in shard order —
//! it is the scheduling half of the engine's determinism contract (see
//! [`crate::serve`]); only the formed batches execute in parallel.

use super::queue::RequestQueue;
use super::request::Request;

/// Batch formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests coalesced into one shard pass (1 = no batching).
    pub max_batch: usize,
    /// Prefer a lead request matching the shard's resident model (within
    /// the top priority level), avoiding a weight switch.
    pub prefer_resident: bool,
    /// DVFS-tier filter: when set, the coalesced tail only admits
    /// requests whose priority maps to the same tier as the lead
    /// (`tier_of(priority)`). A batch runs at one operating point, so
    /// under the `slo` DVFS policy this keeps a boost-tier batch from
    /// dragging interactive requests down to a best-effort corner (or
    /// burning boost energy on batch-tier fillers). `None` = coalesce
    /// across tiers (every fixed-point policy).
    pub tier_of: Option<fn(u8) -> usize>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, prefer_resident: true, tier_of: None }
    }
}

/// Form the next batch for a shard whose resident model is `resident`.
/// Returns `None` when the queue is empty. The returned batch is
/// non-empty and single-model; the coalesced tail behind the lead is
/// ordered earliest-deadline-first ([`RequestQueue::drain_model`]), so
/// within a priority level tighter SLOs finish earlier. The lead itself
/// is chosen priority-first, so a high-priority lead may legitimately
/// precede a tail member with a tighter deadline.
pub fn next_batch(
    queue: &mut RequestQueue,
    resident: Option<usize>,
    policy: &BatchPolicy,
) -> Option<Vec<Request>> {
    assert!(policy.max_batch >= 1);
    let lead = queue.pop_lead(if policy.prefer_resident { resident } else { None })?;
    let model = lead.model;
    let lead_priority = lead.priority;
    let mut batch = vec![lead];
    if policy.max_batch > 1 {
        match policy.tier_of {
            Some(tier) => {
                let want = tier(lead_priority);
                batch.extend(queue.drain_model_where(model, policy.max_batch - 1, |r| {
                    tier(r.priority) == want
                }));
            }
            None => batch.extend(queue.drain_model(model, policy.max_batch - 1)),
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QTensor;
    use crate::util::{proptest, Prng};

    fn req(id: u64, model: usize, priority: u8) -> Request {
        Request {
            id,
            model,
            class: 0,
            priority,
            arrival_cycle: id,
            deadline: None,
            input: QTensor::zeros(&[1, 1, 8], 8, false),
        }
    }

    #[test]
    fn coalesces_same_model_up_to_max() {
        let mut q = RequestQueue::new(16);
        for (id, m) in [(0, 0), (1, 1), (2, 0), (3, 0), (4, 0)] {
            q.push(req(id, m, 0));
        }
        let policy = BatchPolicy { max_batch: 3, prefer_resident: false, ..BatchPolicy::default() };
        let batch = next_batch(&mut q, None, &policy).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert!(batch.iter().all(|r| r.model == 0));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn affinity_keeps_shard_on_resident_model() {
        let mut q = RequestQueue::new(16);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 0));
        let policy = BatchPolicy { max_batch: 4, prefer_resident: true, ..BatchPolicy::default() };
        let batch = next_batch(&mut q, Some(1), &policy).unwrap();
        assert_eq!(batch[0].model, 1);
    }

    #[test]
    fn max_batch_one_disables_coalescing() {
        let mut q = RequestQueue::new(16);
        q.push(req(0, 0, 0));
        q.push(req(1, 0, 0));
        let policy = BatchPolicy { max_batch: 1, prefer_resident: false, ..BatchPolicy::default() };
        assert_eq!(next_batch(&mut q, None, &policy).unwrap().len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn batch_is_edf_ordered_behind_the_lead() {
        let mut q = RequestQueue::new(16);
        let mut a = req(0, 0, 0);
        a.deadline = Some(800);
        let mut b = req(1, 0, 0);
        b.deadline = Some(200);
        q.push(a);
        q.push(b);
        q.push(req(2, 0, 0)); // best-effort goes last
        let policy = BatchPolicy { max_batch: 4, prefer_resident: false, ..BatchPolicy::default() };
        let batch = next_batch(&mut q, None, &policy).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 0, 2]);
    }

    /// With a DVFS-tier filter installed, same-model requests of a
    /// different tier stay queued (one batch = one operating point) and
    /// form their own batch next round — nothing is dropped.
    #[test]
    fn tier_filter_keeps_batches_single_operating_point() {
        fn tier(priority: u8) -> usize {
            priority.min(2) as usize
        }
        let mut q = RequestQueue::new(16);
        q.push(req(0, 0, 2));
        q.push(req(1, 0, 2));
        q.push(req(2, 0, 0)); // same model, lower tier
        let policy = BatchPolicy { max_batch: 4, prefer_resident: false, tier_of: Some(tier) };
        let batch = next_batch(&mut q, None, &policy).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let rest = next_batch(&mut q, None, &policy).unwrap();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(q.is_empty());
    }

    /// Property: over random queue contents, batches formed until the
    /// queue drains (a) never mix models, (b) are non-empty and bounded
    /// by `max_batch`, (c) lead with a top-priority request, (d) are
    /// EDF-ordered within each priority level, and (e) account for every
    /// admitted request exactly once.
    #[test]
    fn prop_batches_single_model_bounded_and_edf() {
        proptest::check_default(
            |rng: &mut Prng| {
                let n = rng.range(1, 40);
                let max_batch = rng.range(1, 6);
                let reqs: Vec<(usize, u8, Option<u64>)> = (0..n)
                    .map(|_| {
                        let model = rng.range(0, 3);
                        let prio = rng.range(0, 3) as u8;
                        let dl = rng.chance(0.5).then(|| rng.below(1000));
                        (model, prio, dl)
                    })
                    .collect();
                (max_batch, reqs)
            },
            |(max_batch, reqs)| {
                let mut q = RequestQueue::new(64);
                for (id, &(model, prio, dl)) in reqs.iter().enumerate() {
                    let mut r = req(id as u64, model, prio);
                    r.deadline = dl;
                    q.push(r);
                }
                let policy = BatchPolicy {
                    max_batch: *max_batch,
                    prefer_resident: true,
                    ..BatchPolicy::default()
                };
                let mut seen = vec![false; reqs.len()];
                let mut resident = None;
                while let Some(batch) = next_batch(&mut q, resident, &policy) {
                    if batch.is_empty() || batch.len() > *max_batch {
                        return Err(format!("batch size {} (max {max_batch})", batch.len()));
                    }
                    let model = batch[0].model;
                    if batch.iter().any(|r| r.model != model) {
                        return Err("batch mixes models".into());
                    }
                    // the lead must carry the top priority among the
                    // requests that were still queued at formation time
                    let top = reqs
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| !seen[*i])
                        .map(|(_, &(_, p, _))| p)
                        .max()
                        .unwrap_or(0);
                    if batch[0].priority != top {
                        return Err(format!(
                            "lead priority {} != queued max {top}",
                            batch[0].priority
                        ));
                    }
                    for w in batch[1..].windows(2) {
                        if w[0].deadline_key() > w[1].deadline_key() {
                            return Err("batch tail not EDF-ordered".into());
                        }
                    }
                    for r in &batch {
                        let i = r.id as usize;
                        if seen[i] {
                            return Err(format!("request {i} served twice"));
                        }
                        seen[i] = true;
                    }
                    resident = Some(model);
                }
                if !seen.iter().all(|&s| s) {
                    return Err("request lost (never batched)".into());
                }
                Ok(())
            },
        );
    }
}
