//! QIR text-format invariants: every committed zoo file parses and
//! reprints byte-identically, the Rust graph builders export exactly the
//! committed bytes, randomized graphs survive `print -> parse -> print`
//! as a fixed point, and the worked example in `docs/QIR_FORMAT.md` is
//! live (parsed verbatim and compared against the builder).

use flexv::models;
use flexv::qnn::graph::{Graph, OpKind};
use flexv::qnn::{qir, QuantParams};
use flexv::util::Prng;

#[test]
fn committed_zoo_files_reprint_byte_identically() {
    for name in models::ZOO_NAMES {
        let text = models::committed_qir(name).expect("zoo model has a committed .qir");
        let g = qir::parse(text).unwrap_or_else(|e| panic!("models/{name}.qir: {e}"));
        assert_eq!(
            qir::print(&g),
            text,
            "models/{name}.qir is not in canonical form — regenerate with tools/gen_qir.py"
        );
    }
}

#[test]
fn graph_builders_export_the_committed_bytes() {
    // `flexv qir export <model>` must agree with the committed file — the
    // same byte-diff the qir CI job performs through the CLI. For the
    // paper networks this pins the Rust graph builders to the files; the
    // extension models are read back from the files, so this degenerates
    // to the reprint identity for them.
    for name in models::ZOO_NAMES {
        let g = models::graph_by_name(name, 224).expect("zoo graph");
        let text = models::committed_qir(name).unwrap();
        assert_eq!(
            qir::print(&g),
            text,
            "{name}: `qir export` output drifted from models/{name}.qir"
        );
    }
}

#[test]
fn format_doc_worked_example_is_live() {
    let doc = include_str!("../../docs/QIR_FORMAT.md");
    let marker = "```qir\n";
    let start = doc.find(marker).expect("QIR_FORMAT.md carries a ```qir worked example");
    let body = &doc[start + marker.len()..];
    assert!(!body.contains(marker), "exactly one ```qir fence so the test is unambiguous");
    let end = body.find("\n```").expect("worked example fence is closed");
    let text = format!("{}\n", &body[..end]);
    // The worked example IS the committed ResNet-20 4b2b zoo file, parsed
    // verbatim and equal to the graph the builder produces.
    assert_eq!(text, models::committed_qir("resnet20-4b2b").unwrap());
    let g = qir::parse(&text).unwrap_or_else(|e| panic!("worked example must parse: {e}"));
    assert_eq!(g, models::resnet20_graph(models::Profile::Mixed4a2w, 12));
}

/// Draw a random valid graph: a conv stem, then a random chain of ops
/// respecting the format's shape/precision rules, with occasional
/// residual adds, concats and per-op seed overrides.
fn random_graph(rng: &mut Prng) -> Graph {
    let hw = 4 + 2 * rng.below(5) as usize; // 4..=12
    let c0 = 8 * (1 + rng.below(3) as usize); // 8, 16, 24
    let seed = rng.next_u64() % 1_000_000;
    let mut g = Graph::new(&format!("rand-{seed}"), [hw, hw, c0], 8, seed);
    let mut prev = g.input;
    let (mut shape, bits) = ([hw, hw, c0], 8u8);
    let n_ops = 2 + rng.below(5) as usize;
    for i in 0..n_ops {
        let choice = rng.below(5);
        let name = format!("n{i}");
        match choice {
            0 => {
                // 3x3 conv, new channel count
                let c = 8 * (1 + rng.below(3) as usize);
                let quant = QuantParams::scalar(1, 8, 0, bits, c);
                let w = [2u8, 4, 8][rng.below(3) as usize];
                shape = [shape[0], shape[1], c];
                prev = g.op(
                    &name,
                    OpKind::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
                    &[prev],
                    w,
                    shape,
                    quant,
                    (rng.below(4) == 0).then(|| rng.next_u64() % 999),
                );
            }
            1 => {
                // depthwise 3x3
                let quant = QuantParams::scalar(1, rng.below(12) as u8, 0, bits, shape[2]);
                prev = g.op(
                    &name,
                    OpKind::DwConv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
                    &[prev],
                    4,
                    shape,
                    quant,
                    None,
                );
            }
            2 => {
                // residual: pointwise branch + add back
                let quant = QuantParams::scalar(1, rng.below(12) as u8, 0, bits, shape[2]);
                let b = g.op(
                    &format!("{name}b"),
                    OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
                    &[prev],
                    4,
                    shape,
                    quant,
                    None,
                );
                let quant = QuantParams::scalar(1, rng.below(12) as u8, 0, bits, shape[2]);
                prev = g.op(&name, OpKind::Add { m1: 1, m2: 1 }, &[b, prev], 8, shape, quant, None);
            }
            3 => {
                // concat of two pointwise halves
                let c = shape[2];
                let qa = QuantParams::scalar(1, rng.below(12) as u8, 0, bits, c);
                let a = g.op(
                    &format!("{name}a"),
                    OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
                    &[prev],
                    4,
                    shape,
                    qa,
                    None,
                );
                let qb = QuantParams::scalar(1, rng.below(12) as u8, 0, bits, c);
                let b = g.op(
                    &format!("{name}b"),
                    OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
                    &[prev],
                    8,
                    shape,
                    qb,
                    None,
                );
                shape = [shape[0], shape[1], 2 * c];
                let quant = QuantParams::scalar(1, 0, 0, bits, 2 * c);
                prev = g.op(&name, OpKind::Concat, &[a, b], 8, shape, quant, None);
            }
            _ => {
                // 2x2 maxpool when the map is still big enough
                if shape[0] >= 4 {
                    shape = [shape[0] / 2, shape[1] / 2, shape[2]];
                    let quant = QuantParams::scalar(1, 0, 0, bits, shape[2]);
                    prev = g.op(
                        &name,
                        OpKind::MaxPool { k: 2, stride: 2 },
                        &[prev],
                        8,
                        shape,
                        quant,
                        None,
                    );
                }
            }
        }
    }
    // classifier head
    let quant = QuantParams::scalar(1, 9, 0, 8, 8);
    g.op("fc", OpKind::Linear, &[prev], 8, [1, 1, 8], quant, None);
    g
}

#[test]
fn randomized_graphs_roundtrip_as_a_fixed_point() {
    let mut rng = Prng::new(0x01D_F0B1);
    for case in 0..64 {
        let g = random_graph(&mut rng);
        g.validate().unwrap_or_else(|e| panic!("case {case}: generator built invalid graph: {e}"));
        let once = qir::print(&g);
        let parsed = qir::parse(&once).unwrap_or_else(|e| panic!("case {case}: {e}\n{once}"));
        assert_eq!(parsed, g, "case {case}: parse must invert print");
        assert_eq!(qir::print(&parsed), once, "case {case}: print must be byte-stable");
    }
}
