//! Per-request and fleet-level serving metrics.
//!
//! Everything is measured in simulated cluster cycles (deterministic);
//! wall-clock figures are derived at the typical-corner frequency
//! ([`crate::report::F_TYP_MHZ`], 250 MHz). The engine's determinism
//! contract (see [`crate::serve`]) makes every **simulated** field a
//! pure function of the trace, diffable across machines, worker
//! counts, and fast-path settings — the parallelism tests assert
//! exactly that. The one exception is the host-side simulator
//! fast-path counters (`fastpath_*`): they describe how the simulation
//! was computed (and can vary with thread interleaving on a shared
//! window cache), never what it computed.

use crate::report::F_TYP_MHZ;
use crate::util::table::{f, Table};

use super::cache::PlanCache;
use super::queue::RequestQueue;
use super::request::Completion;
use super::shard::Shard;

/// Nearest-rank percentile over an ascending-sorted slice.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Aggregates for one registered model.
#[derive(Clone, Debug)]
pub struct ModelRow {
    pub name: String,
    pub served: usize,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_exec_cycles: f64,
    pub macs_per_cycle: f64,
    /// Mean simulated energy per request [µJ].
    pub energy_uj: f64,
}

/// The fleet-level report of one serving run.
#[derive(Clone, Debug)]
pub struct FleetMetrics {
    pub shards: usize,
    pub served: usize,
    pub enqueued: u64,
    pub rejected: u64,
    pub peak_queue_depth: usize,
    /// First arrival → last completion, simulated cycles.
    pub span_cycles: u64,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_latency_cycles: f64,
    /// Throughput at the typical corner.
    pub requests_per_sec: f64,
    /// Total MACs / span cycles — the fleet-level Table IV metric.
    pub aggregate_macs_per_cycle: f64,
    /// Total MACs / Σ busy cycles — per-shard efficiency while working.
    pub busy_macs_per_cycle: f64,
    /// Σ busy / (shards × span).
    pub shard_utilization: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: usize,
    pub batches: u64,
    pub mean_batch: f64,
    pub model_switches: u64,
    /// Simulator windows replayed purely from a memoized functional
    /// delta, across all shards (host-side metric; see `sim::fastpath`).
    pub fastpath_pure: u64,
    /// Simulator windows with replayed timing + functional re-execution.
    pub fastpath_func: u64,
    /// Simulator windows cycle-simulated and recorded.
    pub fastpath_miss: u64,
    pub rows: Vec<ModelRow>,
}

impl FleetMetrics {
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }

    pub(crate) fn collect(
        completions: &[Completion],
        names: &[String],
        queue: &RequestQueue,
        cache: &PlanCache,
        shards: &[Shard],
    ) -> FleetMetrics {
        let served = completions.len();
        let mut latencies: Vec<u64> = completions.iter().map(|c| c.latency_cycles()).collect();
        latencies.sort_unstable();
        let first_arrival = completions.iter().map(|c| c.arrival_cycle).min().unwrap_or(0);
        let last_finish = completions.iter().map(|c| c.finish_cycle).max().unwrap_or(0);
        let span_cycles = last_finish.saturating_sub(first_arrival);
        let total_macs: u64 = completions.iter().map(|c| c.macs).sum();
        let total_exec: u64 = completions.iter().map(|c| c.exec_cycles).sum();
        let total_busy: u64 = shards.iter().map(|s| s.busy_cycles).sum();
        let batches: u64 = shards.iter().map(|s| s.batches).sum();
        let span_secs = span_cycles as f64 / (F_TYP_MHZ * 1e6);
        let (mut fp_pure, mut fp_func, mut fp_miss) = (0u64, 0u64, 0u64);
        for s in shards {
            let (p, f, m) = s.fastpath_counts();
            fp_pure += p;
            fp_func += f;
            fp_miss += m;
        }

        let rows = names
            .iter()
            .enumerate()
            .map(|(m, name)| {
                let of_model: Vec<&Completion> =
                    completions.iter().filter(|c| c.model == m).collect();
                let mut lat: Vec<u64> = of_model.iter().map(|c| c.latency_cycles()).collect();
                lat.sort_unstable();
                let n = of_model.len();
                let exec: u64 = of_model.iter().map(|c| c.exec_cycles).sum();
                let macs: u64 = of_model.iter().map(|c| c.macs).sum();
                let pj: f64 = of_model.iter().map(|c| c.energy_pj).sum();
                ModelRow {
                    name: name.clone(),
                    served: n,
                    p50_cycles: percentile(&lat, 0.50),
                    p99_cycles: percentile(&lat, 0.99),
                    mean_exec_cycles: exec as f64 / n.max(1) as f64,
                    macs_per_cycle: macs as f64 / exec.max(1) as f64,
                    energy_uj: pj / n.max(1) as f64 * 1e-6,
                }
            })
            .collect();

        FleetMetrics {
            shards: shards.len(),
            served,
            enqueued: queue.enqueued,
            rejected: queue.rejected,
            peak_queue_depth: queue.peak_depth,
            span_cycles,
            p50_cycles: percentile(&latencies, 0.50),
            p99_cycles: percentile(&latencies, 0.99),
            mean_latency_cycles: latencies.iter().sum::<u64>() as f64 / served.max(1) as f64,
            requests_per_sec: if span_secs > 0.0 { served as f64 / span_secs } else { 0.0 },
            aggregate_macs_per_cycle: total_macs as f64 / span_cycles.max(1) as f64,
            busy_macs_per_cycle: total_macs as f64 / total_exec.max(1) as f64,
            shard_utilization: if span_cycles > 0 && !shards.is_empty() {
                total_busy as f64 / (shards.len() as f64 * span_cycles as f64)
            } else {
                0.0
            },
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_entries: cache.len(),
            batches,
            mean_batch: served as f64 / batches.max(1) as f64,
            model_switches: shards.iter().map(|s| s.model_switches).sum(),
            fastpath_pure: fp_pure,
            fastpath_func: fp_func,
            fastpath_miss: fp_miss,
            rows,
        }
    }

    /// Render the throughput/latency table plus fleet summary lines.
    pub fn render(&self) -> String {
        let ms = |cyc: u64| cyc as f64 / (F_TYP_MHZ * 1e3);
        let mut t = Table::new(format!(
            "serve fleet — {} shards, {} requests ({} rejected), {} Mcycle span",
            self.shards,
            self.served,
            self.rejected,
            self.span_cycles / 1_000_000
        ))
        .header(&["model", "served", "p50[ms]", "p99[ms]", "MAC/cyc", "uJ/req"]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.served.to_string(),
                f(ms(r.p50_cycles), 2),
                f(ms(r.p99_cycles), 2),
                f(r.macs_per_cycle, 1),
                f(r.energy_uj, 1),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "throughput: {} req/s @ {} MHz | latency p50/p99: {}/{} ms | mean {} ms\n",
            f(self.requests_per_sec, 1),
            f(F_TYP_MHZ, 0),
            f(ms(self.p50_cycles), 2),
            f(ms(self.p99_cycles), 2),
            f(self.mean_latency_cycles / (F_TYP_MHZ * 1e3), 2),
        ));
        out.push_str(&format!(
            "fleet: {} MAC/cyc aggregate ({} while busy), utilization {}%, peak queue {}\n",
            f(self.aggregate_macs_per_cycle, 1),
            f(self.busy_macs_per_cycle, 1),
            f(self.shard_utilization * 100.0, 0),
            self.peak_queue_depth,
        ));
        out.push_str(&format!(
            "plan cache: {} hits / {} misses ({}% hit rate), {} compiled plans | batches: {} (mean {}/batch), model switches: {}\n",
            self.cache_hits,
            self.cache_misses,
            f(self.cache_hit_rate() * 100.0, 0),
            self.cache_entries,
            self.batches,
            f(self.mean_batch, 1),
            self.model_switches,
        ));
        let fp_total = self.fastpath_pure + self.fastpath_func + self.fastpath_miss;
        if fp_total > 0 {
            out.push_str(&format!(
                "sim fast path: {} pure + {} functional replays / {} windows ({}% replayed; host-side only)\n",
                self.fastpath_pure,
                self.fastpath_func,
                fp_total,
                f((self.fastpath_pure + self.fastpath_func) as f64 / fp_total as f64 * 100.0, 0),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 0.5), 51); // round(99*0.5)=50 -> v[50]
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }
}
