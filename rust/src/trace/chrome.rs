//! Chrome trace-event JSON exporter.
//!
//! Serializes a [`Recorder`] into the Chrome trace-event format (the
//! JSON-object flavour with a `traceEvents` array), loadable in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`. Timestamps are the
//! recorder's simulated cycles written into the format's microsecond
//! `ts` field one-to-one — one displayed microsecond is one cycle, which
//! keeps every number exact (`f64` holds integers up to 2^53, far beyond
//! any simulated span).
//!
//! Serialization reuses the byte-deterministic [`Json`] writer from
//! [`crate::report::artifact`]: insertion-ordered objects, shortest
//! round-trip numbers, fixed two-space layout. Export a canonicalized
//! recorder ([`Recorder::canonicalize`]) and the bytes are a pure
//! function of the recorded events — the CI trace gate byte-diffs
//! exports across worker counts and fast-path settings.
//!
//! [`to_chrome_json`] emits [`Scope::Sim`] events only — the
//! deterministic cycle-domain payload. [`to_chrome_json_with_host`]
//! additionally includes host-scope events (fast-path record/replay
//! outcomes, cross-checks) for debugging; those vary with the fast-path
//! setting by nature, so they are excluded from determinism artifacts.

use super::{Arg, Event, Payload, Recorder, Scope};
use crate::report::artifact::Json;

/// Export the recorder's sim-scope events as Chrome trace-event JSON
/// (deterministic bytes; see the module docs).
pub fn to_chrome_json(rec: &Recorder) -> String {
    render(rec, false)
}

/// Export all events including host-scope ones (debugging aid; not
/// byte-stable across fast-path settings).
pub fn to_chrome_json_with_host(rec: &Recorder) -> String {
    render(rec, true)
}

fn render(rec: &Recorder, include_host: bool) -> String {
    let mut events: Vec<Json> = Vec::new();
    // Naming metadata first, sorted by id so the export never depends on
    // the order tracks were first touched.
    let mut procs: Vec<(u32, &str)> =
        rec.processes().iter().map(|(p, n)| (*p, n.as_str())).collect();
    procs.sort();
    for (pid, name) in procs {
        events.push(meta_event("process_name", pid, 0, name));
    }
    let mut threads: Vec<(u32, u32, &str)> =
        rec.threads().iter().map(|(p, t, n)| (*p, *t, n.as_str())).collect();
    threads.sort();
    for (pid, tid, name) in threads {
        events.push(meta_event("thread_name", pid, tid, name));
    }
    for ev in rec.events() {
        if ev.scope == Scope::Host && !include_host {
            continue;
        }
        events.push(event_json(ev));
    }
    Json::Obj(vec![
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
        ("traceEvents".to_string(), Json::Arr(events)),
    ])
    .render()
}

/// A `"M"` metadata event naming a process or thread.
fn meta_event(kind: &str, pid: u32, tid: u32, name: &str) -> Json {
    Json::Obj(vec![
        ("name".to_string(), Json::Str(kind.to_string())),
        ("ph".to_string(), Json::Str("M".to_string())),
        ("pid".to_string(), Json::Num(pid as f64)),
        ("tid".to_string(), Json::Num(tid as f64)),
        (
            "args".to_string(),
            Json::Obj(vec![("name".to_string(), Json::Str(name.to_string()))]),
        ),
    ])
}

fn arg_json(a: &Arg) -> Json {
    match a {
        Arg::U64(v) => Json::Num(*v as f64),
        Arg::F64(v) => Json::Num(*v),
        Arg::Str(s) => Json::Str(s.clone()),
    }
}

fn event_json(ev: &Event) -> Json {
    let cat = match ev.scope {
        Scope::Sim => "sim",
        Scope::Host => "host",
    };
    let mut o: Vec<(String, Json)> = vec![
        ("name".to_string(), Json::Str(ev.name.clone())),
        ("cat".to_string(), Json::Str(cat.to_string())),
    ];
    let ph = match ev.payload {
        Payload::Span { .. } => "X",
        Payload::Instant => "i",
        Payload::Counter { .. } => "C",
    };
    o.push(("ph".to_string(), Json::Str(ph.to_string())));
    o.push(("ts".to_string(), Json::Num(ev.at as f64)));
    if let Payload::Span { dur } = ev.payload {
        o.push(("dur".to_string(), Json::Num(dur as f64)));
    }
    o.push(("pid".to_string(), Json::Num(ev.track.pid as f64)));
    o.push(("tid".to_string(), Json::Num(ev.track.tid as f64)));
    if let Payload::Instant = ev.payload {
        // thread-scoped instant (the small arrow marker)
        o.push(("s".to_string(), Json::Str("t".to_string())));
    }
    let mut args: Vec<(String, Json)> = Vec::new();
    if let Payload::Counter { value } = ev.payload {
        // counter tracks plot each args series; ours carry one value
        args.push(("value".to_string(), Json::Num(value)));
    }
    for (k, a) in &ev.args {
        args.push(((*k).to_string(), arg_json(a)));
    }
    if !args.is_empty() {
        o.push(("args".to_string(), Json::Obj(args)));
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::track;

    fn sample() -> Recorder {
        let mut r = Recorder::new();
        r.name_process(0, "cluster");
        r.name_thread(track(0, 1), "core0");
        r.span(Scope::Sim, track(0, 1), "conv", 10, 90, vec![("macs", Arg::U64(128))]);
        r.instant(Scope::Host, track(0, 0), "fastpath_record", 10, vec![]);
        r.counter(Scope::Sim, track(0, 0), "active_shards", 10, 2.0);
        r.canonicalize();
        r
    }

    #[test]
    fn export_is_valid_json_with_expected_fields() {
        let s = to_chrome_json(&sample());
        let j = Json::parse(&s).expect("exporter must emit parseable JSON");
        let evs = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        // 2 metadata + span + counter; the host instant is excluded
        assert_eq!(evs.len(), 4);
        for ev in evs {
            assert!(ev.get("name").is_some() && ev.get("ph").is_some());
            assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        }
        let span = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .expect("one span");
        assert_eq!(span.get("ts").and_then(Json::as_f64), Some(10.0));
        assert_eq!(span.get("dur").and_then(Json::as_f64), Some(90.0));
        assert_eq!(
            span.get("args").and_then(|a| a.get("macs")).and_then(Json::as_f64),
            Some(128.0)
        );
        let counter = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .expect("one counter");
        assert_eq!(
            counter.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn host_events_only_in_debug_export() {
        let rec = sample();
        let plain = to_chrome_json(&rec);
        let debug = to_chrome_json_with_host(&rec);
        assert!(!plain.contains("fastpath_record"));
        assert!(debug.contains("fastpath_record"));
    }

    #[test]
    fn export_bytes_are_reproducible() {
        assert_eq!(to_chrome_json(&sample()), to_chrome_json(&sample()));
    }
}
