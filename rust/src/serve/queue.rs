//! Bounded admission queue with priorities and rejection accounting.
//!
//! The queue is the engine's saturation mechanism: when the fleet falls
//! behind the arrival process, depth grows to `capacity` and further
//! arrivals are **rejected** (counted, never silently dropped) — bounded
//! memory and an explicit load-shedding signal instead of unbounded
//! latency collapse.
//!
//! Admission policy notes (tested below):
//! - rejection is priority-blind: a full queue rejects a high-priority
//!   arrival rather than evicting a queued low-priority request —
//!   admitted work is never preempted, so acceptance is monotone in
//!   arrival order and the engine stays deterministic;
//! - `capacity == 0` is valid and admits nothing (drain/canary
//!   configurations);
//! - service order is priority-first, FIFO within a level, with an
//!   optional resident-model affinity that never crosses priority
//!   levels ([`RequestQueue::pop_lead`]).

use std::collections::VecDeque;

use super::request::Request;

/// FIFO-within-priority bounded queue.
pub struct RequestQueue {
    capacity: usize,
    items: VecDeque<Request>,
    /// Requests accepted over the queue's lifetime.
    pub enqueued: u64,
    /// Requests refused because the queue was full.
    pub rejected: u64,
    /// High-water mark of the depth.
    pub peak_depth: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        RequestQueue {
            capacity,
            items: VecDeque::new(),
            enqueued: 0,
            rejected: 0,
            peak_depth: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a request; returns false (and counts a rejection) when full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            return false;
        }
        self.items.push_back(req);
        self.enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.items.len());
        true
    }

    /// Remove and return the request that should lead the next batch:
    /// highest priority first, FIFO within a priority level. When
    /// `affinity` names a model and a request for it exists at the top
    /// priority level, the oldest such request is preferred — keeping a
    /// shard on its resident model avoids the L3→L2 weight-switch cost.
    pub fn pop_lead(&mut self, affinity: Option<usize>) -> Option<Request> {
        let pmax = self.items.iter().map(|r| r.priority).max()?;
        let idx = affinity
            .and_then(|m| {
                self.items
                    .iter()
                    .position(|r| r.priority == pmax && r.model == m)
            })
            .or_else(|| self.items.iter().position(|r| r.priority == pmax))?;
        self.items.remove(idx)
    }

    /// Remove up to `max` queued requests for `model` (oldest first,
    /// any priority) — the batch-coalescing primitive.
    pub fn drain_model(&mut self, model: usize, max: usize) -> Vec<Request> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.items.len() && out.len() < max {
            if self.items[i].model == model {
                out.push(self.items.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QTensor;

    fn req(id: u64, model: usize, priority: u8) -> Request {
        Request {
            id,
            model,
            priority,
            arrival_cycle: id,
            input: QTensor::zeros(&[1, 1, 8], 8, false),
        }
    }

    #[test]
    fn bounded_with_rejections() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        assert!(!q.push(req(2, 0, 0)));
        assert_eq!((q.enqueued, q.rejected, q.peak_depth), (2, 1, 2));
    }

    #[test]
    fn priority_then_fifo() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 2));
        q.push(req(2, 2, 2));
        q.push(req(3, 0, 1));
        assert_eq!(q.pop_lead(None).unwrap().id, 1); // oldest of prio 2
        assert_eq!(q.pop_lead(None).unwrap().id, 2);
        assert_eq!(q.pop_lead(None).unwrap().id, 3); // prio 1 before prio 0
        assert_eq!(q.pop_lead(None).unwrap().id, 0);
        assert!(q.pop_lead(None).is_none());
    }

    #[test]
    fn affinity_prefers_resident_model_within_top_priority() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 0));
        // same priority: affinity to model 1 overrides FIFO
        assert_eq!(q.pop_lead(Some(1)).unwrap().id, 1);
        // but never crosses priority levels
        q.push(req(2, 1, 0));
        q.push(req(3, 0, 1));
        assert_eq!(q.pop_lead(Some(1)).unwrap().id, 3);
    }

    /// A full queue rejects newcomers regardless of priority: admitted
    /// work is never preempted, even by a higher-priority arrival, and
    /// the queued order is untouched by the rejected push.
    #[test]
    fn full_queue_rejects_high_priority_without_preemption() {
        let mut q = RequestQueue::new(3);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 1)));
        assert!(q.push(req(2, 0, 0)));
        // queue full: top-priority arrival is rejected, not swapped in
        assert!(!q.push(req(3, 0, 7)));
        assert!(!q.push(req(4, 0, 0)));
        assert_eq!((q.enqueued, q.rejected, q.len()), (3, 2, 3));
        // service order of the admitted requests is unchanged
        assert_eq!(q.pop_lead(None).unwrap().id, 1);
        assert_eq!(q.pop_lead(None).unwrap().id, 0);
        assert_eq!(q.pop_lead(None).unwrap().id, 2);
        // rejections freed no capacity accounting
        assert!(q.is_empty());
        assert_eq!(q.capacity(), 3);
    }

    /// `capacity == 0` is a valid drain configuration: every push is
    /// rejected and counted, and every consumer sees an empty queue.
    #[test]
    fn zero_capacity_queue_admits_nothing() {
        let mut q = RequestQueue::new(0);
        for id in 0..4 {
            assert!(!q.push(req(id, 0, (id % 3) as u8)));
        }
        assert_eq!((q.enqueued, q.rejected, q.peak_depth), (0, 4, 0));
        assert!(q.is_empty());
        assert!(q.pop_lead(None).is_none());
        assert!(q.pop_lead(Some(0)).is_none());
        assert!(q.drain_model(0, 8).is_empty());
    }

    #[test]
    fn drain_model_coalesces_in_order() {
        let mut q = RequestQueue::new(8);
        for (id, m) in [(0, 0), (1, 1), (2, 0), (3, 0), (4, 1)] {
            q.push(req(id, m, 0));
        }
        let batch = q.drain_model(0, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.drain_model(0, 9).len(), 1); // id 3 remains
    }
}
