//! End-to-end driver: deploy the paper's ResNet-20 4b2b through the DORY
//! flow and run real inferences on the simulated cluster, on all four
//! cores — proving every layer composes: network zoo -> tiling solver ->
//! double-buffered DMA -> per-ISA kernels -> requantized outputs, with the
//! result checked bit-exactly against the golden integer executor.
//!
//!     cargo run --release --example e2e_resnet20

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::deploy;
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::models::{resnet20, Profile};
use flexv::power::EnergyModel;
use flexv::qnn::{golden, QTensor};
use flexv::util::Prng;

fn main() {
    let net = resnet20(Profile::Mixed4a2w, 12);
    println!(
        "{}: {} nodes, {:.1} MMAC, {:.0} kB weights",
        net.name,
        net.nodes.len(),
        net.total_macs() as f64 / 1e6,
        net.model_bytes() as f64 / 1024.0
    );
    let mut rng = Prng::new(2024);
    // A batch of synthetic CIFAR-10-like inputs.
    let inputs: Vec<QTensor> =
        (0..3).map(|_| QTensor::random(&[32, 32, 4], 8, false, &mut rng)).collect();
    let em = EnergyModel::default();

    for isa in IsaVariant::ALL {
        let dep = deploy(&net, isa, MemBudget::default());
        let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
        let mut cycles_total = 0u64;
        let t0 = std::time::Instant::now();
        for input in &inputs {
            let golden_out = golden::run_network(&net, input);
            let res = coord.run(&dep, input);
            assert_eq!(
                res.output,
                golden_out.last().unwrap().data,
                "{isa}: simulated output != golden"
            );
            cycles_total += res.total_cycles();
        }
        let wall = t0.elapsed();
        let cycles = cycles_total / inputs.len() as u64;
        let fmax = flexv::power::phys(isa).fmax_mhz;
        let lat_ms = cycles as f64 / (fmax * 1e3);
        let macs = net.total_macs() as f64;
        println!(
            "{:<8} {:>9} cycles/inf  {:>6.2} ms @ {:.0} MHz  {:>5.1} MAC/cyc  (batch of {}, sim {:.1}s, outputs verified)",
            isa.name(),
            cycles,
            lat_ms,
            fmax,
            macs / cycles as f64,
            inputs.len(),
            wall.as_secs_f64(),
        );
        let _ = &em;
    }
    println!("paper Table IV ResNet20 row: XpulpV2 4.8, XpulpNN 4.4, Flex-V 11.2 MAC/cycle");
}
